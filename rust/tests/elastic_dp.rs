//! Integration tests for the elastic fault-tolerant DP backend.
//!
//! The contract under test (DESIGN.md "Elasticity and recovery contract"):
//! the loss trajectory is a function of the shard set only, so worker
//! deaths, stragglers, dropped/duplicated/delayed messages, mid-run joins
//! and checkpoint/resume must all reproduce the fault-free single-worker
//! trajectory bit-for-bit.

use std::path::PathBuf;

use zo2::dp::{
    checkpoint, params_fingerprint, run_elastic, ElasticRunConfig, FaultSchedule, RunOutcome,
    TransportKind,
};

/// The trajectory as raw bit patterns — equality here is bit-identity.
fn records_bits(o: &RunOutcome) -> Vec<(u64, u32, u32, u32)> {
    o.records
        .iter()
        .map(|r| (r.step, r.loss_plus.to_bits(), r.loss_minus.to_bits(), r.g.to_bits()))
        .collect()
}

/// The canonical reference: one worker, no faults, in-process channels.
fn reference(shards: usize, steps: u64) -> RunOutcome {
    run_elastic(&ElasticRunConfig::quick(1, shards, steps)).expect("fault-free K=1 run")
}

fn tmp(name: &str) -> PathBuf {
    std::env::temp_dir().join(format!("zo2_elastic_{}_{name}", std::process::id()))
}

#[test]
fn trajectory_is_invariant_across_worker_counts() {
    let base = reference(4, 16);
    assert_eq!(base.records.len(), 16);
    for k in [2usize, 3, 4] {
        let out = run_elastic(&ElasticRunConfig::quick(k, 4, 16)).unwrap();
        assert_eq!(records_bits(&base), records_bits(&out), "K={k} trajectory");
        assert_eq!(
            params_fingerprint(&base.final_snap.params),
            params_fingerprint(&out.final_snap.params),
            "K={k} final params"
        );
        assert_eq!((out.deaths, out.joins), (0, 0), "K={k} saw phantom membership churn");
    }
}

#[test]
fn seeded_fault_schedules_reproduce_the_fault_free_trajectory() {
    // Property over seeds: every generated schedule (≥1 kill, a delayed and
    // a duplicated reply, a dropped commit, a stall, and one mid-run join)
    // leaves the trajectory bit-identical to the fault-free K=1 run.
    let steps = 24u64;
    let base = reference(4, steps);
    for seed in [1u64, 7, 23] {
        let mut cfg = ElasticRunConfig::quick(3, 4, steps);
        cfg.schedule =
            FaultSchedule::parse(&format!("seeded:{seed}"), cfg.workers, steps).unwrap();
        let out = run_elastic(&cfg).unwrap_or_else(|e| panic!("seed {seed}: {e:#}"));
        assert_eq!(records_bits(&base), records_bits(&out), "seed {seed} trajectory");
        assert_eq!(
            params_fingerprint(&base.final_snap.params),
            params_fingerprint(&out.final_snap.params),
            "seed {seed} final params"
        );
        assert!(out.deaths >= 1, "seed {seed}: the scheduled kill must register as a death");
        assert_eq!(out.joins, 1, "seed {seed}: the scheduled joiner must be admitted");
    }
}

#[test]
fn checkpoint_then_resume_continues_the_exact_trajectory() {
    let steps = 24u64;
    let base = reference(4, steps);
    let path = tmp("resume.pool");
    checkpoint::remove_checkpoint(&path);

    // Phase 1: run the first half with periodic checkpoints; the run ends
    // ("crashes") at step 12, having persisted its state to the DiskPool.
    let mut cfg = ElasticRunConfig::quick(2, 4, 12);
    cfg.checkpoint = Some(path.clone());
    cfg.checkpoint_every = 5;
    let first = run_elastic(&cfg).unwrap();
    assert_eq!(first.records.len(), 12);
    assert!(path.exists(), "checkpoint pool must exist after the first run");

    // Phase 2: resume from the checkpoint toward the full target.
    let mut cfg = ElasticRunConfig::quick(2, 4, steps);
    cfg.checkpoint = Some(path.clone());
    cfg.resume = true;
    let second = run_elastic(&cfg).unwrap();
    assert_eq!(second.records.first().map(|r| r.step), Some(12), "resume start step");

    let mut stitched = records_bits(&first);
    stitched.extend(records_bits(&second));
    assert_eq!(records_bits(&base), stitched, "resumed trajectory diverged");
    assert_eq!(
        params_fingerprint(&base.final_snap.params),
        params_fingerprint(&second.final_snap.params),
        "resumed final params"
    );
    checkpoint::remove_checkpoint(&path);
}

#[test]
fn socket_transports_match_the_chan_reference() {
    let base = reference(4, 8);

    let sock = tmp("smoke.sock");
    let _ = std::fs::remove_file(&sock);
    let mut cfg = ElasticRunConfig::quick(2, 4, 8);
    cfg.transport = TransportKind::Unix(sock.clone());
    let out = run_elastic(&cfg).unwrap();
    assert_eq!(records_bits(&base), records_bits(&out), "unix transport trajectory");

    let mut cfg = ElasticRunConfig::quick(3, 4, 8);
    cfg.transport = TransportKind::Tcp("127.0.0.1:0".to_string());
    let out = run_elastic(&cfg).unwrap();
    assert_eq!(records_bits(&base), records_bits(&out), "tcp transport trajectory");
}

#[test]
fn explicit_kill_join_and_message_faults_preserve_the_trajectory() {
    use zo2::telemetry::metrics;

    let steps = 16u64;
    let base = reference(4, steps);

    metrics::set_enabled(true);
    metrics::global().reset();
    let spec = "kill:w1@5,join:w3@9,delay:losses:w0@3:2,dup:losses:w2@2,drop:commit:w2@4";
    let mut cfg = ElasticRunConfig::quick(3, 4, steps);
    cfg.schedule = FaultSchedule::parse(spec, cfg.workers, steps).unwrap();
    let out = run_elastic(&cfg).unwrap();
    let snap = metrics::global().snapshot_json();
    metrics::set_enabled(false);

    assert_eq!(records_bits(&base), records_bits(&out), "faulted trajectory");
    assert_eq!(out.deaths, 1, "exactly the scheduled kill");
    assert_eq!(out.joins, 1, "exactly the scheduled join");
    let reassigned = metrics::find_value(&snap, "zo2_dp_reassigned_shards", &[]).unwrap_or(0.0);
    assert!(reassigned >= 1.0, "the killed worker's shards must be reassigned: {reassigned}");
}
