//! Golden compatibility: the device-indexed scheduler must be a pure
//! re-indexing of the original single-device 5-stream scheduler, and the
//! microbatched pipeline builder a pure generalisation of the PR 3
//! multi-device builder.
//!
//! `reference_v1` below is a **frozen copy** of the pre-refactor
//! `build_plan` + `simulate` (the hard-coded `Stream` enum, stream-name
//! busy map and global disk-batch state), kept verbatim as the golden
//! oracle.  Every test drives both implementations over the same inputs
//! and demands *exact* equality: identical task sequences (kind, module,
//! step, deps, stream↔(device 0, kind) mapping) and bitwise-identical
//! schedules (start/end times, makespan, steady-state step time, per-stream
//! busy seconds, bottleneck diagnosis).  `N = 1` is the degenerate case of
//! the sharded builder — not a special case — and this is the proof.
//!
//! `reference_pipeline_v2` is the second freeze, taken when intra-step
//! microbatching landed: a verbatim copy of the PR 3 *multi-device
//! pipeline* builder and the per-`StreamId` simulator.  The microbatched
//! builder at `M = 1` must reproduce it bitwise — tasks, deps, times, busy
//! maps, per-device and cluster bottlenecks — across random policies
//! (including three-tier spills, both placements, both layouts, 1–4
//! devices) and the paper-scale cluster cost model.

use zo2::costmodel::{ComputeMode, Hardware, SimCost, Workload};
use zo2::model::opt_by_name;
use zo2::precision::Codec;
use zo2::rng::GaussianRng;
use zo2::sched::{
    build_plan, simulate, CostProvider, DeviceId, Module, Policy, StreamKind, TaskKind, Tiering,
};

/// Frozen pre-refactor scheduler (PR 2 state).  Do not edit — it is the
/// golden oracle for the device-indexed refactor.
mod reference_v1 {
    use std::collections::HashMap;
    use zo2::sched::{CostProvider, Module, Policy, Tiering};

    #[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
    pub enum Stream {
        Upload,
        Compute,
        Offload,
        DiskRead,
        DiskWrite,
    }

    #[derive(Debug, Clone, Copy, PartialEq, Eq)]
    pub enum TaskKind {
        Upload,
        Compute,
        Offload,
        Update,
        DiskRead,
        DiskWrite,
    }

    #[derive(Debug, Clone)]
    pub struct Task {
        pub id: usize,
        pub step: usize,
        pub module: Module,
        pub kind: TaskKind,
        pub stream: Stream,
        pub deps: Vec<usize>,
        pub extra_latency: f64,
    }

    pub struct Schedule {
        pub start: Vec<f64>,
        pub end: Vec<f64>,
        pub makespan: f64,
        pub steady_step_s: f64,
        pub busy: HashMap<&'static str, f64>,
    }

    impl Schedule {
        pub fn busy_of(&self, stream: &str) -> f64 {
            self.busy.get(stream).copied().unwrap_or(0.0)
        }

        pub fn bottleneck(&self) -> &'static str {
            let compute = self.busy_of("compute");
            let pcie = self.busy_of("upload").max(self.busy_of("offload"));
            let disk = self.busy_of("disk_read").max(self.busy_of("disk_write"));
            if disk >= pcie && disk >= compute {
                "disk-bound"
            } else if pcie >= compute {
                "pcie-bound"
            } else {
                "compute-bound"
            }
        }
    }

    pub fn build_plan(n_blocks: usize, steps: usize, policy: Policy) -> Vec<Task> {
        let mut tasks: Vec<Task> = Vec::new();
        let mut last_on: [Option<usize>; 5] = [None; 5];
        let mut offload_ring: Vec<Option<usize>> = vec![None; policy.slots.max(1)];
        let mut ring_pos = 0usize;
        let mut dram_ring: Vec<Option<usize>> = vec![None; policy.dram_slots.max(1)];
        let mut dram_pos = 0usize;
        let mut last_write: Vec<Option<usize>> = vec![None; n_blocks];
        let mut prev_any: Option<usize> = None;
        let mut prev_compute: Option<usize> = None;

        let spilled = match policy.tiering {
            Tiering::TwoTier => 0,
            Tiering::ThreeTier => policy.spilled.min(n_blocks),
        };
        let on_disk = |i: usize| i >= n_blocks - spilled;

        let stream_idx = |s: Stream| match s {
            Stream::Upload => 0,
            Stream::Compute => 1,
            Stream::Offload => 2,
            Stream::DiskRead => 3,
            Stream::DiskWrite => 4,
        };

        let push = |tasks: &mut Vec<Task>,
                        last_on: &mut [Option<usize>; 5],
                        prev_any: &mut Option<usize>,
                        prev_compute: &mut Option<usize>,
                        step: usize,
                        module: Module,
                        kind: TaskKind,
                        mut deps: Vec<usize>,
                        extra_latency: f64| {
            let stream = if policy.overlap {
                match kind {
                    TaskKind::Upload => Stream::Upload,
                    TaskKind::Compute | TaskKind::Update => Stream::Compute,
                    TaskKind::Offload => Stream::Offload,
                    TaskKind::DiskRead => Stream::DiskRead,
                    TaskKind::DiskWrite => Stream::DiskWrite,
                }
            } else {
                Stream::Compute
            };
            let id = tasks.len();
            if let Some(p) = last_on[stream_idx(stream)] {
                deps.push(p);
            }
            if !policy.overlap {
                if let Some(p) = *prev_any {
                    deps.push(p);
                }
            }
            deps.sort_unstable();
            deps.dedup();
            tasks.push(Task { id, step, module, kind, stream, deps, extra_latency });
            last_on[stream_idx(stream)] = Some(id);
            *prev_any = Some(id);
            if matches!(kind, TaskKind::Compute | TaskKind::Update) {
                *prev_compute = Some(id);
            }
            id
        };

        let malloc_sync = !policy.reusable_mem;

        for step in 0..steps {
            let c_embed = push(&mut tasks, &mut last_on, &mut prev_any, &mut prev_compute,
                               step, Module::Embed, TaskKind::Compute, vec![], 0.0);
            let mut prev_c = c_embed;

            for i in 0..n_blocks {
                let mut deps = Vec::new();
                if on_disk(i) {
                    let mut rdeps = Vec::new();
                    if let Some(w) = dram_ring[dram_pos] {
                        rdeps.push(w);
                    }
                    if let Some(w) = last_write[i] {
                        rdeps.push(w);
                    }
                    let r = push(&mut tasks, &mut last_on, &mut prev_any, &mut prev_compute,
                                 step, Module::Block(i), TaskKind::DiskRead, rdeps, 0.0);
                    deps.push(r);
                }
                if let Some(o) = offload_ring[ring_pos] {
                    deps.push(o);
                }
                if malloc_sync {
                    if let Some(c) = prev_compute {
                        deps.push(c);
                    }
                }
                let extra = 0.0;
                let u = push(&mut tasks, &mut last_on, &mut prev_any, &mut prev_compute,
                             step, Module::Block(i), TaskKind::Upload, deps, extra);

                let c = push(&mut tasks, &mut last_on, &mut prev_any, &mut prev_compute,
                             step, Module::Block(i), TaskKind::Compute, vec![u, prev_c], 0.0);
                prev_c = c;

                let o = push(&mut tasks, &mut last_on, &mut prev_any, &mut prev_compute,
                             step, Module::Block(i), TaskKind::Offload, vec![c], 0.0);
                offload_ring[ring_pos] = Some(o);
                ring_pos = (ring_pos + 1) % offload_ring.len();

                if on_disk(i) {
                    let w = push(&mut tasks, &mut last_on, &mut prev_any, &mut prev_compute,
                                 step, Module::Block(i), TaskKind::DiskWrite, vec![o], 0.0);
                    dram_ring[dram_pos] = Some(w);
                    dram_pos = (dram_pos + 1) % dram_ring.len();
                    last_write[i] = Some(w);
                }
            }

            let _c_head = push(&mut tasks, &mut last_on, &mut prev_any, &mut prev_compute,
                               step, Module::Head, TaskKind::Compute, vec![prev_c], 0.0);

            if !policy.efficient_update {
                for i in 0..n_blocks {
                    let mut deps = Vec::new();
                    if on_disk(i) {
                        let mut rdeps = Vec::new();
                        if let Some(w) = dram_ring[dram_pos] {
                            rdeps.push(w);
                        }
                        if let Some(w) = last_write[i] {
                            rdeps.push(w);
                        }
                        let r = push(&mut tasks, &mut last_on, &mut prev_any, &mut prev_compute,
                                     step, Module::Block(i), TaskKind::DiskRead, rdeps, 0.0);
                        deps.push(r);
                    }
                    if let Some(o) = offload_ring[ring_pos] {
                        deps.push(o);
                    }
                    if malloc_sync {
                        if let Some(c) = prev_compute {
                            deps.push(c);
                        }
                    }
                    let u = push(&mut tasks, &mut last_on, &mut prev_any, &mut prev_compute,
                                 step, Module::Block(i), TaskKind::Upload, deps, 0.0);
                    let c = push(&mut tasks, &mut last_on, &mut prev_any, &mut prev_compute,
                                 step, Module::Block(i), TaskKind::Update, vec![u], 0.0);
                    let o = push(&mut tasks, &mut last_on, &mut prev_any, &mut prev_compute,
                                 step, Module::Block(i), TaskKind::Offload, vec![c], 0.0);
                    offload_ring[ring_pos] = Some(o);
                    ring_pos = (ring_pos + 1) % offload_ring.len();
                    if on_disk(i) {
                        let w = push(&mut tasks, &mut last_on, &mut prev_any, &mut prev_compute,
                                     step, Module::Block(i), TaskKind::DiskWrite, vec![o], 0.0);
                        dram_ring[dram_pos] = Some(w);
                        dram_pos = (dram_pos + 1) % dram_ring.len();
                        last_write[i] = Some(w);
                    }
                }
            }
        }
        tasks
    }

    fn stream_name(s: Stream) -> &'static str {
        match s {
            Stream::Upload => "upload",
            Stream::Compute => "compute",
            Stream::Offload => "offload",
            Stream::DiskRead => "disk_read",
            Stream::DiskWrite => "disk_write",
        }
    }

    pub fn simulate(tasks: &[Task], costs: &dyn CostProvider, policy: Policy) -> Schedule {
        let mut start = vec![0.0f64; tasks.len()];
        let mut end = vec![0.0f64; tasks.len()];
        let mut stream_free: HashMap<Stream, f64> = HashMap::new();
        let mut busy: HashMap<&'static str, f64> = HashMap::new();
        let mut read_batch_len = 0usize;
        let mut last_was_read: HashMap<Stream, bool> = HashMap::new();

        for t in tasks {
            let stream_prev: f64 = *stream_free.get(&t.stream).unwrap_or(&0.0);
            let mut t0 = stream_prev;
            for &d in &t.deps {
                t0 = t0.max(end[d]);
            }
            t0 += t.extra_latency;
            let dur = match t.kind {
                TaskKind::Upload => {
                    let base = costs.upload_s() + costs.host_decode_s();
                    if policy.reusable_mem { base } else { base + costs.malloc_s() }
                }
                TaskKind::Compute => costs.compute_s(t.module),
                TaskKind::Offload => costs.offload_s() + costs.host_encode_s(),
                TaskKind::Update => costs.update_s(),
                TaskKind::DiskRead => {
                    let queued = t0 <= stream_prev + 1e-12;
                    let coalesce = policy.disk_batch > 1
                        && queued
                        && last_was_read.get(&t.stream).copied().unwrap_or(false)
                        && read_batch_len > 0
                        && read_batch_len < policy.disk_batch;
                    if coalesce {
                        read_batch_len += 1;
                        costs.disk_read_bw_s()
                    } else {
                        read_batch_len = 1;
                        costs.disk_read_s()
                    }
                }
                TaskKind::DiskWrite => costs.disk_write_s(),
            };
            last_was_read.insert(t.stream, t.kind == TaskKind::DiskRead);
            let t1 = t0 + dur;
            start[t.id] = t0;
            end[t.id] = t1;
            stream_free.insert(t.stream, t1);
            *busy.entry(stream_name(t.stream)).or_default() += dur;
        }

        let makespan = end.iter().copied().fold(0.0, f64::max);
        let n_steps = tasks.iter().map(|t| t.step).max().map(|s| s + 1).unwrap_or(0);
        let steady_step_s = if n_steps >= 2 {
            let mut step_end = vec![0.0f64; n_steps];
            for t in tasks {
                step_end[t.step] = step_end[t.step].max(end[t.id]);
            }
            (step_end[n_steps - 1] - step_end[0]) / (n_steps - 1) as f64
        } else {
            makespan
        };

        Schedule { start, end, makespan, steady_step_s, busy }
    }
}

/// Map a refactored task kind back onto the v1 enum (link kinds never
/// appear in single-device plans — asserted by the caller).
fn v1_kind(kind: TaskKind) -> reference_v1::TaskKind {
    match kind {
        TaskKind::Upload => reference_v1::TaskKind::Upload,
        TaskKind::Compute => reference_v1::TaskKind::Compute,
        TaskKind::Offload => reference_v1::TaskKind::Offload,
        TaskKind::Update => reference_v1::TaskKind::Update,
        TaskKind::DiskRead => reference_v1::TaskKind::DiskRead,
        TaskKind::DiskWrite => reference_v1::TaskKind::DiskWrite,
        k => panic!("link task {k:?} in a single-device plan"),
    }
}

fn v1_stream_kind(s: reference_v1::Stream) -> StreamKind {
    match s {
        reference_v1::Stream::Upload => StreamKind::Upload,
        reference_v1::Stream::Compute => StreamKind::Compute,
        reference_v1::Stream::Offload => StreamKind::Offload,
        reference_v1::Stream::DiskRead => StreamKind::DiskRead,
        reference_v1::Stream::DiskWrite => StreamKind::DiskWrite,
    }
}

fn assert_plans_identical(new: &[zo2::sched::Task], old: &[reference_v1::Task], what: &str) {
    assert_eq!(new.len(), old.len(), "{what}: task count");
    for (n, o) in new.iter().zip(old) {
        assert_eq!(n.id, o.id, "{what}: id");
        assert_eq!(n.step, o.step, "{what}: task {} step", n.id);
        assert_eq!(n.module, o.module, "{what}: task {} module", n.id);
        assert_eq!(v1_kind(n.kind), o.kind, "{what}: task {} kind", n.id);
        assert_eq!(n.device(), DeviceId(0), "{what}: task {} off device 0", n.id);
        assert_eq!(
            n.stream.kind,
            v1_stream_kind(o.stream),
            "{what}: task {} stream",
            n.id
        );
        assert_eq!(n.deps, o.deps, "{what}: task {} deps", n.id);
        assert!(
            n.extra_latency == o.extra_latency,
            "{what}: task {} extra latency",
            n.id
        );
    }
}

fn assert_schedules_identical(
    new: &zo2::sched::Schedule,
    old: &reference_v1::Schedule,
    what: &str,
) {
    // Bitwise: the refactor may not perturb a single f64.
    for (i, (a, b)) in new.start.iter().zip(&old.start).enumerate() {
        assert!(a.to_bits() == b.to_bits(), "{what}: start[{i}] {a} vs {b}");
    }
    for (i, (a, b)) in new.end.iter().zip(&old.end).enumerate() {
        assert!(a.to_bits() == b.to_bits(), "{what}: end[{i}] {a} vs {b}");
    }
    assert!(new.makespan.to_bits() == old.makespan.to_bits(), "{what}: makespan");
    assert!(
        new.steady_step_s.to_bits() == old.steady_step_s.to_bits(),
        "{what}: steady step"
    );
    for name in ["upload", "compute", "offload", "disk_read", "disk_write"] {
        assert!(
            new.busy_of(name).to_bits() == old.busy_of(name).to_bits(),
            "{what}: busy[{name}] {} vs {}",
            new.busy_of(name),
            old.busy_of(name)
        );
    }
    assert_eq!(new.bottleneck(), old.bottleneck(), "{what}: bottleneck");
}

struct RandCosts {
    up: f64,
    off: f64,
    comp: f64,
    upd: f64,
    read: f64,
    write: f64,
    host: f64,
}

impl CostProvider for RandCosts {
    fn upload_s(&self) -> f64 {
        self.up
    }
    fn offload_s(&self) -> f64 {
        self.off
    }
    fn compute_s(&self, m: Module) -> f64 {
        self.comp * if m == Module::Embed { 0.3 } else { 1.0 }
    }
    fn update_s(&self) -> f64 {
        self.upd
    }
    fn host_decode_s(&self) -> f64 {
        self.host
    }
    fn host_encode_s(&self) -> f64 {
        self.host
    }
    fn disk_read_s(&self) -> f64 {
        self.read
    }
    fn disk_read_bw_s(&self) -> f64 {
        self.read * 0.6
    }
    fn disk_write_s(&self) -> f64 {
        self.write
    }
}

fn rand_case(rng: &mut GaussianRng) -> (usize, usize, RandCosts, Policy) {
    let n_blocks = 1 + rng.next_below(12) as usize;
    let steps = 1 + rng.next_below(4) as usize;
    let costs = RandCosts {
        up: 0.01 + rng.next_uniform() * 2.0,
        off: 0.01 + rng.next_uniform() * 2.0,
        comp: 0.01 + rng.next_uniform() * 4.0,
        upd: 0.01 + rng.next_uniform() * 0.5,
        read: 0.01 + rng.next_uniform() * 3.0,
        write: 0.01 + rng.next_uniform() * 3.0,
        host: rng.next_uniform() * 0.5,
    };
    let three = rng.next_below(2) == 0;
    // spill_placement stays Trailing: that IS the pre-refactor semantics
    // (interleaved placement is new behaviour with no v1 counterpart).
    let policy = Policy {
        overlap: rng.next_below(4) != 0,
        reusable_mem: rng.next_below(2) == 0,
        efficient_update: rng.next_below(2) == 0,
        slots: 1 + rng.next_below(4) as usize,
        tiering: if three { Tiering::ThreeTier } else { Tiering::TwoTier },
        spilled: if three { rng.next_below(1 + n_blocks as u64) as usize } else { 0 },
        dram_slots: 1 + rng.next_below(4) as usize,
        disk_batch: 1 + rng.next_below(4) as usize,
        ..Policy::default()
    };
    (n_blocks, steps, costs, policy)
}

#[test]
fn refactored_plan_is_byte_identical_to_v1_across_random_cases() {
    let mut rng = GaussianRng::new(0x60_1D, 0);
    for case in 0..200 {
        let (n, steps, costs, policy) = rand_case(&mut rng);
        let new_plan = build_plan(n, steps, policy);
        let old_plan = reference_v1::build_plan(n, steps, policy);
        assert_plans_identical(&new_plan, &old_plan, &format!("case {case} ({policy:?})"));

        let (new_sched, _) = simulate(&new_plan, &costs, policy);
        let old_sched = reference_v1::simulate(&old_plan, &costs, policy);
        assert_schedules_identical(&new_sched, &old_sched, &format!("case {case}"));
    }
}

#[test]
fn paper_scale_cost_breakdown_matches_v1() {
    // The acceptance check behind `simulate --devices 1`: same schedule,
    // same cost breakdown, same bottleneck diagnosis as before the
    // refactor, on the real calibrated cost model at paper scale.
    let hw = Hardware::a100_pcie4();
    let cases = [
        ("OPT-13B", Codec::F32, ComputeMode::Fp32, Policy::default()),
        ("OPT-13B", Codec::Fp16, ComputeMode::Fp16, Policy::default()),
        ("OPT-13B", Codec::F32, ComputeMode::Fp32, Policy::naive()),
        ("OPT-175B", Codec::Fp16, ComputeMode::Fp16, Policy::three_tier(70, 4)),
        (
            "OPT-175B",
            Codec::Fp16,
            ComputeMode::Fp16,
            Policy { disk_batch: 4, ..Policy::three_tier(70, 4) },
        ),
    ];
    for (name, wire, compute, policy) in cases {
        let wl = Workload {
            shape: opt_by_name(name).unwrap(),
            batch: 1,
            seq: 2048,
            wire,
            compute,
        };
        let costs = SimCost::new(&hw, &wl);
        let new_plan = build_plan(wl.shape.n_layers, 4, policy);
        let old_plan = reference_v1::build_plan(wl.shape.n_layers, 4, policy);
        assert_plans_identical(&new_plan, &old_plan, name);
        let (new_sched, _) = simulate(&new_plan, &costs, policy);
        let old_sched = reference_v1::simulate(&old_plan, &costs, policy);
        assert_schedules_identical(&new_sched, &old_sched, name);
    }
}

// ===========================================================================
// Freeze #2: the PR 3 multi-device pipeline builder + per-StreamId simulator,
// copied verbatim when intra-step microbatching landed.  Do not edit.
// ===========================================================================

mod reference_pipeline_v2 {
    use std::collections::HashMap;
    use zo2::sched::{
        is_spilled_block, CostProvider, DeviceId, Module, Policy, StreamId, StreamKind, TaskKind,
        Tiering,
    };
    use zo2::shard::{block_owner, ShardLayout};

    #[derive(Debug, Clone)]
    pub struct RefTask {
        pub id: usize,
        pub step: usize,
        pub module: Module,
        pub kind: TaskKind,
        pub stream: StreamId,
        pub deps: Vec<usize>,
        pub extra_latency: f64,
    }

    fn sk_index(k: StreamKind) -> usize {
        match k {
            StreamKind::Upload => 0,
            StreamKind::Compute => 1,
            StreamKind::Offload => 2,
            StreamKind::DiskRead => 3,
            StreamKind::DiskWrite => 4,
            StreamKind::Interconnect => 5,
        }
    }

    struct Lane {
        device: DeviceId,
        last_on: [Option<usize>; 6],
        offload_ring: Vec<Option<usize>>,
        ring_pos: usize,
        dram_ring: Vec<Option<usize>>,
        dram_pos: usize,
        prev_compute: Option<usize>,
        prev_any: Option<usize>,
    }

    impl Lane {
        fn new(device: usize, policy: &Policy) -> Self {
            Self {
                device: DeviceId(device),
                last_on: [None; 6],
                offload_ring: vec![None; policy.slots.max(1)],
                ring_pos: 0,
                dram_ring: vec![None; policy.dram_slots.max(1)],
                dram_pos: 0,
                prev_compute: None,
                prev_any: None,
            }
        }
    }

    struct PlanBuilder {
        tasks: Vec<RefTask>,
        policy: Policy,
    }

    impl PlanBuilder {
        fn new(policy: Policy) -> Self {
            Self { tasks: Vec::new(), policy }
        }

        fn push(
            &mut self,
            lane: &mut Lane,
            step: usize,
            module: Module,
            kind: TaskKind,
            mut deps: Vec<usize>,
            extra_latency: f64,
        ) -> usize {
            let stream_kind = if self.policy.overlap {
                kind.stream_kind()
            } else {
                StreamKind::Compute
            };
            let stream = StreamId { device: lane.device, kind: stream_kind };
            let id = self.tasks.len();
            if let Some(p) = lane.last_on[sk_index(stream_kind)] {
                deps.push(p);
            }
            if !self.policy.overlap {
                if let Some(p) = lane.prev_any {
                    deps.push(p);
                }
            }
            deps.sort_unstable();
            deps.dedup();
            self.tasks.push(RefTask { id, step, module, kind, stream, deps, extra_latency });
            lane.last_on[sk_index(stream_kind)] = Some(id);
            lane.prev_any = Some(id);
            if matches!(kind, TaskKind::Compute | TaskKind::Update) {
                lane.prev_compute = Some(id);
            }
            id
        }

        #[allow(clippy::too_many_arguments)]
        fn push_block_round(
            &mut self,
            lane: &mut Lane,
            step: usize,
            block: usize,
            on_disk: bool,
            last_write: &mut Option<usize>,
            compute_kind: TaskKind,
            compute_extra_deps: &[usize],
        ) -> usize {
            let module = Module::Block(block);
            let mut deps = Vec::new();
            if on_disk {
                let mut rdeps = Vec::new();
                if let Some(w) = lane.dram_ring[lane.dram_pos] {
                    rdeps.push(w);
                }
                if let Some(w) = *last_write {
                    rdeps.push(w);
                }
                let r = self.push(lane, step, module, TaskKind::DiskRead, rdeps, 0.0);
                deps.push(r);
            }
            if let Some(o) = lane.offload_ring[lane.ring_pos] {
                deps.push(o);
            }
            if !self.policy.reusable_mem {
                if let Some(c) = lane.prev_compute {
                    deps.push(c);
                }
            }
            let u = self.push(lane, step, module, TaskKind::Upload, deps, 0.0);

            let mut cdeps = vec![u];
            cdeps.extend_from_slice(compute_extra_deps);
            let c = self.push(lane, step, module, compute_kind, cdeps, 0.0);

            let o = self.push(lane, step, module, TaskKind::Offload, vec![c], 0.0);
            lane.offload_ring[lane.ring_pos] = Some(o);
            lane.ring_pos = (lane.ring_pos + 1) % lane.offload_ring.len();

            if on_disk {
                let w = self.push(lane, step, module, TaskKind::DiskWrite, vec![o], 0.0);
                lane.dram_ring[lane.dram_pos] = Some(w);
                lane.dram_pos = (lane.dram_pos + 1) % lane.dram_ring.len();
                *last_write = Some(w);
            }
            c
        }
    }

    fn spilled_count(policy: &Policy, n_blocks: usize) -> usize {
        match policy.tiering {
            Tiering::TwoTier => 0,
            Tiering::ThreeTier => policy.spilled.min(n_blocks),
        }
    }

    pub fn pipeline_plan(
        n_blocks: usize,
        steps: usize,
        policy: Policy,
        devices: usize,
        layout: ShardLayout,
    ) -> Vec<RefTask> {
        let mut b = PlanBuilder::new(policy);
        let mut lanes: Vec<Lane> = (0..devices).map(|d| Lane::new(d, &policy)).collect();
        let mut last_write: Vec<Option<usize>> = vec![None; n_blocks];
        let spilled = spilled_count(&policy, n_blocks);
        let on_disk = |i: usize| is_spilled_block(i, n_blocks, spilled, policy.spill_placement);
        let owner = |i: usize| block_owner(layout, n_blocks, devices, i);
        let head_dev = if n_blocks == 0 { 0 } else { owner(n_blocks - 1) };
        let mut grad_bcast: Option<usize> = None;

        for step in 0..steps {
            let mut edeps = Vec::new();
            if let Some(g) = grad_bcast {
                edeps.push(g);
            }
            let c_embed =
                b.push(&mut lanes[0], step, Module::Embed, TaskKind::Compute, edeps, 0.0);
            let mut prev_c = c_embed;
            let mut prev_dev = 0usize;
            let mut gated = vec![false; devices];
            gated[0] = true;

            for i in 0..n_blocks {
                let d = owner(i);
                let act = if d != prev_dev {
                    b.push(
                        &mut lanes[prev_dev],
                        step,
                        Module::Block(i),
                        TaskKind::ActivationXfer,
                        vec![prev_c],
                        0.0,
                    )
                } else {
                    prev_c
                };
                let mut extra = vec![act];
                if !gated[d] {
                    if let Some(g) = grad_bcast {
                        extra.push(g);
                    }
                    gated[d] = true;
                }
                let c = b.push_block_round(
                    &mut lanes[d],
                    step,
                    i,
                    on_disk(i),
                    &mut last_write[i],
                    TaskKind::Compute,
                    &extra,
                );
                prev_c = c;
                prev_dev = d;
            }

            let c_head = b.push(
                &mut lanes[head_dev],
                step,
                Module::Head,
                TaskKind::Compute,
                vec![prev_c],
                0.0,
            );

            if devices > 1 {
                grad_bcast = Some(b.push(
                    &mut lanes[head_dev],
                    step,
                    Module::Head,
                    TaskKind::GradReduce,
                    vec![c_head],
                    0.0,
                ));
            }

            if !policy.efficient_update {
                let g_dep = grad_bcast;
                let mut upd_gated = vec![false; devices];
                upd_gated[head_dev] = true;
                for i in 0..n_blocks {
                    let d = owner(i);
                    let mut extra = Vec::new();
                    if !upd_gated[d] {
                        if let Some(g) = g_dep {
                            extra.push(g);
                        }
                        upd_gated[d] = true;
                    }
                    b.push_block_round(
                        &mut lanes[d],
                        step,
                        i,
                        on_disk(i),
                        &mut last_write[i],
                        TaskKind::Update,
                        &extra,
                    );
                }
            }
        }
        b.tasks
    }

    pub struct RefSchedule {
        pub start: Vec<f64>,
        pub end: Vec<f64>,
        pub makespan: f64,
        pub steady_step_s: f64,
        pub busy: HashMap<StreamId, f64>,
    }

    fn classify(compute: f64, pcie: f64, disk: f64, ic: f64) -> &'static str {
        if ic > disk && ic > pcie && ic > compute {
            "interconnect-bound"
        } else if disk >= pcie && disk >= compute {
            "disk-bound"
        } else if pcie >= compute {
            "pcie-bound"
        } else {
            "compute-bound"
        }
    }

    impl RefSchedule {
        pub fn busy_on(&self, device: DeviceId, kind: StreamKind) -> f64 {
            self.busy.get(&StreamId { device, kind }).copied().unwrap_or(0.0)
        }

        pub fn devices(&self) -> Vec<DeviceId> {
            let mut ds: Vec<DeviceId> = self.busy.keys().map(|id| id.device).collect();
            ds.sort_unstable();
            ds.dedup();
            ds
        }

        pub fn bottleneck_of(&self, device: DeviceId) -> &'static str {
            let compute = self.busy_on(device, StreamKind::Compute);
            let pcie = self
                .busy_on(device, StreamKind::Upload)
                .max(self.busy_on(device, StreamKind::Offload));
            let disk = self
                .busy_on(device, StreamKind::DiskRead)
                .max(self.busy_on(device, StreamKind::DiskWrite));
            let ic = self.busy_on(device, StreamKind::Interconnect);
            classify(compute, pcie, disk, ic)
        }

        pub fn bottleneck(&self) -> &'static str {
            let mut compute = 0.0f64;
            let mut pcie = 0.0f64;
            let mut disk = 0.0f64;
            for d in self.devices() {
                compute = compute.max(self.busy_on(d, StreamKind::Compute));
                pcie = pcie.max(
                    self.busy_on(d, StreamKind::Upload)
                        .max(self.busy_on(d, StreamKind::Offload)),
                );
                disk = disk.max(
                    self.busy_on(d, StreamKind::DiskRead)
                        .max(self.busy_on(d, StreamKind::DiskWrite)),
                );
            }
            let ic: f64 = self
                .busy
                .iter()
                .filter(|(id, _)| id.kind == StreamKind::Interconnect)
                .map(|(_, &s)| s)
                .sum();
            classify(compute, pcie, disk, ic)
        }
    }

    pub fn simulate(tasks: &[RefTask], costs: &dyn CostProvider, policy: Policy) -> RefSchedule {
        let mut start = vec![0.0f64; tasks.len()];
        let mut end = vec![0.0f64; tasks.len()];
        let mut stream_free: HashMap<StreamId, f64> = HashMap::new();
        let mut busy: HashMap<StreamId, f64> = HashMap::new();
        let mut read_batch_len: HashMap<StreamId, usize> = HashMap::new();
        let mut last_was_read: HashMap<StreamId, bool> = HashMap::new();

        for t in tasks {
            let stream_prev: f64 = *stream_free.get(&t.stream).unwrap_or(&0.0);
            let mut t0 = stream_prev;
            for &d in &t.deps {
                t0 = t0.max(end[d]);
            }
            t0 += t.extra_latency;
            let dur = match t.kind {
                TaskKind::Upload => {
                    let base = costs.upload_s() + costs.host_decode_s();
                    if policy.reusable_mem {
                        base
                    } else {
                        base + costs.malloc_s()
                    }
                }
                TaskKind::Compute => costs.compute_s(t.module),
                TaskKind::Offload => costs.offload_s() + costs.host_encode_s(),
                TaskKind::Update => costs.update_s(),
                TaskKind::DiskRead => {
                    let queued = t0 <= stream_prev + 1e-12;
                    let batch = read_batch_len.entry(t.stream).or_insert(0);
                    let coalesce = policy.disk_batch > 1
                        && queued
                        && last_was_read.get(&t.stream).copied().unwrap_or(false)
                        && *batch > 0
                        && *batch < policy.disk_batch;
                    if coalesce {
                        *batch += 1;
                        costs.disk_read_bw_s()
                    } else {
                        *batch = 1;
                        costs.disk_read_s()
                    }
                }
                TaskKind::DiskWrite => costs.disk_write_s(),
                TaskKind::ActivationXfer => costs.link_activation_s(),
                TaskKind::SeedBcast => costs.link_seed_s(),
                TaskKind::GradReduce => costs.link_grad_s(),
            };
            last_was_read.insert(t.stream, t.kind == TaskKind::DiskRead);
            let t1 = t0 + dur;
            start[t.id] = t0;
            end[t.id] = t1;
            stream_free.insert(t.stream, t1);
            *busy.entry(t.stream).or_default() += dur;
        }

        let makespan = end.iter().copied().fold(0.0, f64::max);
        let n_steps = tasks.iter().map(|t| t.step).max().map(|s| s + 1).unwrap_or(0);
        let steady_step_s = if n_steps >= 2 {
            let mut step_end = vec![0.0f64; n_steps];
            for t in tasks {
                step_end[t.step] = step_end[t.step].max(end[t.id]);
            }
            (step_end[n_steps - 1] - step_end[0]) / (n_steps - 1) as f64
        } else {
            makespan
        };

        RefSchedule { start, end, makespan, steady_step_s, busy }
    }
}

// --- M = 1 microbatched pipeline vs the v2 freeze ---------------------------

use zo2::costmodel::{Cluster, ClusterCost, Interconnect};
use zo2::sched::SpillPlacement;
use zo2::shard::{build_sharded_plan, ShardLayout, ShardSpec};

fn assert_pipeline_plans_identical(
    new: &[zo2::sched::Task],
    old: &[reference_pipeline_v2::RefTask],
    what: &str,
) {
    assert_eq!(new.len(), old.len(), "{what}: task count");
    for (n, o) in new.iter().zip(old) {
        assert_eq!(n.id, o.id, "{what}: id");
        assert_eq!(n.step, o.step, "{what}: task {} step", n.id);
        assert_eq!(n.module, o.module, "{what}: task {} module", n.id);
        assert_eq!(n.kind, o.kind, "{what}: task {} kind", n.id);
        assert_eq!(n.stream, o.stream, "{what}: task {} stream", n.id);
        assert_eq!(n.deps, o.deps, "{what}: task {} deps", n.id);
        assert!(n.extra_latency == o.extra_latency, "{what}: task {} extra latency", n.id);
        assert!(
            n.microbatch.is_none(),
            "{what}: task {} must be untagged at M = 1",
            n.id
        );
    }
}

fn assert_pipeline_schedules_identical(
    new: &zo2::sched::Schedule,
    old: &reference_pipeline_v2::RefSchedule,
    devices: usize,
    what: &str,
) {
    for (i, (a, b)) in new.start.iter().zip(&old.start).enumerate() {
        assert!(a.to_bits() == b.to_bits(), "{what}: start[{i}] {a} vs {b}");
    }
    for (i, (a, b)) in new.end.iter().zip(&old.end).enumerate() {
        assert!(a.to_bits() == b.to_bits(), "{what}: end[{i}] {a} vs {b}");
    }
    assert!(new.makespan.to_bits() == old.makespan.to_bits(), "{what}: makespan");
    assert!(
        new.steady_step_s.to_bits() == old.steady_step_s.to_bits(),
        "{what}: steady step"
    );
    assert_eq!(new.busy.len(), old.busy.len(), "{what}: busy stream count");
    for (id, b) in &old.busy {
        let a = new.busy.get(id).unwrap_or_else(|| panic!("{what}: busy missing {id:?}"));
        assert!(a.to_bits() == b.to_bits(), "{what}: busy[{id:?}] {a} vs {b}");
    }
    assert_eq!(new.bottleneck(), old.bottleneck(), "{what}: bottleneck");
    for d in 0..devices {
        assert_eq!(
            new.bottleneck_of(DeviceId(d)),
            old.bottleneck_of(DeviceId(d)),
            "{what}: bottleneck of device {d}"
        );
    }
}

/// Link-capable random cost provider for the multi-device freeze.
struct RandLinkCosts {
    base: RandCosts,
    act: f64,
    seed: f64,
    grad: f64,
}

impl CostProvider for RandLinkCosts {
    fn upload_s(&self) -> f64 {
        self.base.upload_s()
    }
    fn offload_s(&self) -> f64 {
        self.base.offload_s()
    }
    fn compute_s(&self, m: Module) -> f64 {
        self.base.compute_s(m)
    }
    fn update_s(&self) -> f64 {
        self.base.update_s()
    }
    fn host_decode_s(&self) -> f64 {
        self.base.host_decode_s()
    }
    fn host_encode_s(&self) -> f64 {
        self.base.host_encode_s()
    }
    fn disk_read_s(&self) -> f64 {
        self.base.disk_read_s()
    }
    fn disk_read_bw_s(&self) -> f64 {
        self.base.disk_read_bw_s()
    }
    fn disk_write_s(&self) -> f64 {
        self.base.disk_write_s()
    }
    fn link_activation_s(&self) -> f64 {
        self.act
    }
    fn link_seed_s(&self) -> f64 {
        self.seed
    }
    fn link_grad_s(&self) -> f64 {
        self.grad
    }
}

/// Random multi-device pipeline case: any policy the PR 3 builder accepted,
/// including both spill placements (unlike `rand_case`, whose v1 oracle
/// predates placement).
fn rand_case_v2(
    rng: &mut GaussianRng,
) -> (usize, usize, usize, ShardLayout, RandLinkCosts, Policy) {
    let n_blocks = 1 + rng.next_below(12) as usize;
    let steps = 1 + rng.next_below(4) as usize;
    let devices = 1 + rng.next_below(4) as usize;
    let layout = [ShardLayout::Contiguous, ShardLayout::Cyclic][rng.next_below(2) as usize];
    let costs = RandLinkCosts {
        base: RandCosts {
            up: 0.01 + rng.next_uniform() * 2.0,
            off: 0.01 + rng.next_uniform() * 2.0,
            comp: 0.01 + rng.next_uniform() * 4.0,
            upd: 0.01 + rng.next_uniform() * 0.5,
            read: 0.01 + rng.next_uniform() * 3.0,
            write: 0.01 + rng.next_uniform() * 3.0,
            host: rng.next_uniform() * 0.5,
        },
        act: rng.next_uniform() * 0.5,
        seed: rng.next_uniform() * 0.1,
        grad: rng.next_uniform() * 0.2,
    };
    let three = rng.next_below(2) == 0;
    let policy = Policy {
        overlap: rng.next_below(4) != 0,
        reusable_mem: rng.next_below(2) == 0,
        efficient_update: rng.next_below(2) == 0,
        slots: 1 + rng.next_below(4) as usize,
        tiering: if three { Tiering::ThreeTier } else { Tiering::TwoTier },
        spilled: if three { rng.next_below(1 + n_blocks as u64) as usize } else { 0 },
        spill_placement: if rng.next_below(2) == 0 {
            SpillPlacement::Trailing
        } else {
            SpillPlacement::Interleaved
        },
        dram_slots: 1 + rng.next_below(4) as usize,
        disk_batch: 1 + rng.next_below(4) as usize,
    };
    (n_blocks, steps, devices, layout, costs, policy)
}

#[test]
fn microbatched_pipeline_at_m1_is_byte_identical_to_v2_across_random_cases() {
    let mut rng = GaussianRng::new(0x4D31, 0); // "M1"
    for case in 0..200 {
        let (n, steps, devices, layout, costs, policy) = rand_case_v2(&mut rng);
        let spec = ShardSpec::pipeline_microbatched(devices, layout, 1);
        let new_plan = build_sharded_plan(n, steps, policy, &spec);
        let old_plan = reference_pipeline_v2::pipeline_plan(n, steps, policy, devices, layout);
        let what = format!("case {case} (N={devices} {layout:?} {policy:?})");
        assert_pipeline_plans_identical(&new_plan, &old_plan, &what);

        let (new_sched, _) = simulate(&new_plan, &costs, policy);
        let old_sched = reference_pipeline_v2::simulate(&old_plan, &costs, policy);
        assert_pipeline_schedules_identical(&new_sched, &old_sched, devices, &what);
    }
}

#[test]
fn paper_scale_pipeline_m1_matches_v2_on_the_cluster_cost_model() {
    // The acceptance check behind `simulate --devices N --shard pipeline`:
    // same schedule, same busy maps, same per-device bottleneck diagnosis
    // as the PR 3 builder, on the calibrated cluster cost model.
    let hw = Hardware::a100_pcie4();
    let cases = [
        ("OPT-13B", Codec::Fp16, ComputeMode::Fp16, 2usize, Policy::default()),
        ("OPT-13B", Codec::Fp16, ComputeMode::Fp16, 4, Policy::default()),
        ("OPT-30B", Codec::F32, ComputeMode::Fp32, 4, Policy::naive()),
        ("OPT-175B", Codec::Fp16, ComputeMode::Fp16, 8, Policy::three_tier(70, 4)),
        (
            "OPT-175B",
            Codec::Fp16,
            ComputeMode::Fp16,
            4,
            Policy {
                spill_placement: SpillPlacement::Interleaved,
                disk_batch: 4,
                ..Policy::three_tier(70, 4)
            },
        ),
    ];
    for (name, wire, compute, devices, policy) in cases {
        let wl = Workload {
            shape: opt_by_name(name).unwrap(),
            batch: 1,
            seq: 2048,
            wire,
            compute,
        };
        for layout in [ShardLayout::Contiguous, ShardLayout::Cyclic] {
            let cluster = Cluster::homogeneous(hw.clone(), devices, Interconnect::nvlink());
            let costs = ClusterCost::new(&cluster, &wl).unwrap();
            let spec = ShardSpec::pipeline_microbatched(devices, layout, 1);
            let new_plan = build_sharded_plan(wl.shape.n_layers, 4, policy, &spec);
            let old_plan = reference_pipeline_v2::pipeline_plan(
                wl.shape.n_layers,
                4,
                policy,
                devices,
                layout,
            );
            let what = format!("{name} x{devices} {layout:?}");
            assert_pipeline_plans_identical(&new_plan, &old_plan, &what);
            let (new_sched, _) = simulate(&new_plan, &costs, policy);
            let old_sched = reference_pipeline_v2::simulate(&old_plan, &costs, policy);
            assert_pipeline_schedules_identical(&new_sched, &old_sched, devices, &what);
        }
    }
}

#[test]
fn m1_spec_equals_plain_pipeline_spec() {
    // `pipeline_microbatched(d, l, 1)` and `pipeline(d, l)` are the same
    // spec — there is no separate un-microbatched code path to drift.
    for devices in [1usize, 2, 4] {
        for layout in [ShardLayout::Contiguous, ShardLayout::Cyclic] {
            assert_eq!(
                ShardSpec::pipeline_microbatched(devices, layout, 1),
                ShardSpec::pipeline(devices, layout)
            );
        }
    }
}
