//! Golden compatibility: the device-indexed scheduler must be a pure
//! re-indexing of the original single-device 5-stream scheduler.
//!
//! `reference_v1` below is a **frozen copy** of the pre-refactor
//! `build_plan` + `simulate` (the hard-coded `Stream` enum, stream-name
//! busy map and global disk-batch state), kept verbatim as the golden
//! oracle.  Every test drives both implementations over the same inputs
//! and demands *exact* equality: identical task sequences (kind, module,
//! step, deps, stream↔(device 0, kind) mapping) and bitwise-identical
//! schedules (start/end times, makespan, steady-state step time, per-stream
//! busy seconds, bottleneck diagnosis).  `N = 1` is the degenerate case of
//! the sharded builder — not a special case — and this is the proof.

use zo2::costmodel::{ComputeMode, Hardware, SimCost, Workload};
use zo2::model::opt_by_name;
use zo2::precision::Codec;
use zo2::rng::GaussianRng;
use zo2::sched::{
    build_plan, simulate, CostProvider, DeviceId, Module, Policy, StreamKind, TaskKind, Tiering,
};

/// Frozen pre-refactor scheduler (PR 2 state).  Do not edit — it is the
/// golden oracle for the device-indexed refactor.
mod reference_v1 {
    use std::collections::HashMap;
    use zo2::sched::{CostProvider, Module, Policy, Tiering};

    #[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
    pub enum Stream {
        Upload,
        Compute,
        Offload,
        DiskRead,
        DiskWrite,
    }

    #[derive(Debug, Clone, Copy, PartialEq, Eq)]
    pub enum TaskKind {
        Upload,
        Compute,
        Offload,
        Update,
        DiskRead,
        DiskWrite,
    }

    #[derive(Debug, Clone)]
    pub struct Task {
        pub id: usize,
        pub step: usize,
        pub module: Module,
        pub kind: TaskKind,
        pub stream: Stream,
        pub deps: Vec<usize>,
        pub extra_latency: f64,
    }

    pub struct Schedule {
        pub start: Vec<f64>,
        pub end: Vec<f64>,
        pub makespan: f64,
        pub steady_step_s: f64,
        pub busy: HashMap<&'static str, f64>,
    }

    impl Schedule {
        pub fn busy_of(&self, stream: &str) -> f64 {
            self.busy.get(stream).copied().unwrap_or(0.0)
        }

        pub fn bottleneck(&self) -> &'static str {
            let compute = self.busy_of("compute");
            let pcie = self.busy_of("upload").max(self.busy_of("offload"));
            let disk = self.busy_of("disk_read").max(self.busy_of("disk_write"));
            if disk >= pcie && disk >= compute {
                "disk-bound"
            } else if pcie >= compute {
                "pcie-bound"
            } else {
                "compute-bound"
            }
        }
    }

    pub fn build_plan(n_blocks: usize, steps: usize, policy: Policy) -> Vec<Task> {
        let mut tasks: Vec<Task> = Vec::new();
        let mut last_on: [Option<usize>; 5] = [None; 5];
        let mut offload_ring: Vec<Option<usize>> = vec![None; policy.slots.max(1)];
        let mut ring_pos = 0usize;
        let mut dram_ring: Vec<Option<usize>> = vec![None; policy.dram_slots.max(1)];
        let mut dram_pos = 0usize;
        let mut last_write: Vec<Option<usize>> = vec![None; n_blocks];
        let mut prev_any: Option<usize> = None;
        let mut prev_compute: Option<usize> = None;

        let spilled = match policy.tiering {
            Tiering::TwoTier => 0,
            Tiering::ThreeTier => policy.spilled.min(n_blocks),
        };
        let on_disk = |i: usize| i >= n_blocks - spilled;

        let stream_idx = |s: Stream| match s {
            Stream::Upload => 0,
            Stream::Compute => 1,
            Stream::Offload => 2,
            Stream::DiskRead => 3,
            Stream::DiskWrite => 4,
        };

        let push = |tasks: &mut Vec<Task>,
                        last_on: &mut [Option<usize>; 5],
                        prev_any: &mut Option<usize>,
                        prev_compute: &mut Option<usize>,
                        step: usize,
                        module: Module,
                        kind: TaskKind,
                        mut deps: Vec<usize>,
                        extra_latency: f64| {
            let stream = if policy.overlap {
                match kind {
                    TaskKind::Upload => Stream::Upload,
                    TaskKind::Compute | TaskKind::Update => Stream::Compute,
                    TaskKind::Offload => Stream::Offload,
                    TaskKind::DiskRead => Stream::DiskRead,
                    TaskKind::DiskWrite => Stream::DiskWrite,
                }
            } else {
                Stream::Compute
            };
            let id = tasks.len();
            if let Some(p) = last_on[stream_idx(stream)] {
                deps.push(p);
            }
            if !policy.overlap {
                if let Some(p) = *prev_any {
                    deps.push(p);
                }
            }
            deps.sort_unstable();
            deps.dedup();
            tasks.push(Task { id, step, module, kind, stream, deps, extra_latency });
            last_on[stream_idx(stream)] = Some(id);
            *prev_any = Some(id);
            if matches!(kind, TaskKind::Compute | TaskKind::Update) {
                *prev_compute = Some(id);
            }
            id
        };

        let malloc_sync = !policy.reusable_mem;

        for step in 0..steps {
            let c_embed = push(&mut tasks, &mut last_on, &mut prev_any, &mut prev_compute,
                               step, Module::Embed, TaskKind::Compute, vec![], 0.0);
            let mut prev_c = c_embed;

            for i in 0..n_blocks {
                let mut deps = Vec::new();
                if on_disk(i) {
                    let mut rdeps = Vec::new();
                    if let Some(w) = dram_ring[dram_pos] {
                        rdeps.push(w);
                    }
                    if let Some(w) = last_write[i] {
                        rdeps.push(w);
                    }
                    let r = push(&mut tasks, &mut last_on, &mut prev_any, &mut prev_compute,
                                 step, Module::Block(i), TaskKind::DiskRead, rdeps, 0.0);
                    deps.push(r);
                }
                if let Some(o) = offload_ring[ring_pos] {
                    deps.push(o);
                }
                if malloc_sync {
                    if let Some(c) = prev_compute {
                        deps.push(c);
                    }
                }
                let extra = 0.0;
                let u = push(&mut tasks, &mut last_on, &mut prev_any, &mut prev_compute,
                             step, Module::Block(i), TaskKind::Upload, deps, extra);

                let c = push(&mut tasks, &mut last_on, &mut prev_any, &mut prev_compute,
                             step, Module::Block(i), TaskKind::Compute, vec![u, prev_c], 0.0);
                prev_c = c;

                let o = push(&mut tasks, &mut last_on, &mut prev_any, &mut prev_compute,
                             step, Module::Block(i), TaskKind::Offload, vec![c], 0.0);
                offload_ring[ring_pos] = Some(o);
                ring_pos = (ring_pos + 1) % offload_ring.len();

                if on_disk(i) {
                    let w = push(&mut tasks, &mut last_on, &mut prev_any, &mut prev_compute,
                                 step, Module::Block(i), TaskKind::DiskWrite, vec![o], 0.0);
                    dram_ring[dram_pos] = Some(w);
                    dram_pos = (dram_pos + 1) % dram_ring.len();
                    last_write[i] = Some(w);
                }
            }

            let _c_head = push(&mut tasks, &mut last_on, &mut prev_any, &mut prev_compute,
                               step, Module::Head, TaskKind::Compute, vec![prev_c], 0.0);

            if !policy.efficient_update {
                for i in 0..n_blocks {
                    let mut deps = Vec::new();
                    if on_disk(i) {
                        let mut rdeps = Vec::new();
                        if let Some(w) = dram_ring[dram_pos] {
                            rdeps.push(w);
                        }
                        if let Some(w) = last_write[i] {
                            rdeps.push(w);
                        }
                        let r = push(&mut tasks, &mut last_on, &mut prev_any, &mut prev_compute,
                                     step, Module::Block(i), TaskKind::DiskRead, rdeps, 0.0);
                        deps.push(r);
                    }
                    if let Some(o) = offload_ring[ring_pos] {
                        deps.push(o);
                    }
                    if malloc_sync {
                        if let Some(c) = prev_compute {
                            deps.push(c);
                        }
                    }
                    let u = push(&mut tasks, &mut last_on, &mut prev_any, &mut prev_compute,
                                 step, Module::Block(i), TaskKind::Upload, deps, 0.0);
                    let c = push(&mut tasks, &mut last_on, &mut prev_any, &mut prev_compute,
                                 step, Module::Block(i), TaskKind::Update, vec![u], 0.0);
                    let o = push(&mut tasks, &mut last_on, &mut prev_any, &mut prev_compute,
                                 step, Module::Block(i), TaskKind::Offload, vec![c], 0.0);
                    offload_ring[ring_pos] = Some(o);
                    ring_pos = (ring_pos + 1) % offload_ring.len();
                    if on_disk(i) {
                        let w = push(&mut tasks, &mut last_on, &mut prev_any, &mut prev_compute,
                                     step, Module::Block(i), TaskKind::DiskWrite, vec![o], 0.0);
                        dram_ring[dram_pos] = Some(w);
                        dram_pos = (dram_pos + 1) % dram_ring.len();
                        last_write[i] = Some(w);
                    }
                }
            }
        }
        tasks
    }

    fn stream_name(s: Stream) -> &'static str {
        match s {
            Stream::Upload => "upload",
            Stream::Compute => "compute",
            Stream::Offload => "offload",
            Stream::DiskRead => "disk_read",
            Stream::DiskWrite => "disk_write",
        }
    }

    pub fn simulate(tasks: &[Task], costs: &dyn CostProvider, policy: Policy) -> Schedule {
        let mut start = vec![0.0f64; tasks.len()];
        let mut end = vec![0.0f64; tasks.len()];
        let mut stream_free: HashMap<Stream, f64> = HashMap::new();
        let mut busy: HashMap<&'static str, f64> = HashMap::new();
        let mut read_batch_len = 0usize;
        let mut last_was_read: HashMap<Stream, bool> = HashMap::new();

        for t in tasks {
            let stream_prev: f64 = *stream_free.get(&t.stream).unwrap_or(&0.0);
            let mut t0 = stream_prev;
            for &d in &t.deps {
                t0 = t0.max(end[d]);
            }
            t0 += t.extra_latency;
            let dur = match t.kind {
                TaskKind::Upload => {
                    let base = costs.upload_s() + costs.host_decode_s();
                    if policy.reusable_mem { base } else { base + costs.malloc_s() }
                }
                TaskKind::Compute => costs.compute_s(t.module),
                TaskKind::Offload => costs.offload_s() + costs.host_encode_s(),
                TaskKind::Update => costs.update_s(),
                TaskKind::DiskRead => {
                    let queued = t0 <= stream_prev + 1e-12;
                    let coalesce = policy.disk_batch > 1
                        && queued
                        && last_was_read.get(&t.stream).copied().unwrap_or(false)
                        && read_batch_len > 0
                        && read_batch_len < policy.disk_batch;
                    if coalesce {
                        read_batch_len += 1;
                        costs.disk_read_bw_s()
                    } else {
                        read_batch_len = 1;
                        costs.disk_read_s()
                    }
                }
                TaskKind::DiskWrite => costs.disk_write_s(),
            };
            last_was_read.insert(t.stream, t.kind == TaskKind::DiskRead);
            let t1 = t0 + dur;
            start[t.id] = t0;
            end[t.id] = t1;
            stream_free.insert(t.stream, t1);
            *busy.entry(stream_name(t.stream)).or_default() += dur;
        }

        let makespan = end.iter().copied().fold(0.0, f64::max);
        let n_steps = tasks.iter().map(|t| t.step).max().map(|s| s + 1).unwrap_or(0);
        let steady_step_s = if n_steps >= 2 {
            let mut step_end = vec![0.0f64; n_steps];
            for t in tasks {
                step_end[t.step] = step_end[t.step].max(end[t.id]);
            }
            (step_end[n_steps - 1] - step_end[0]) / (n_steps - 1) as f64
        } else {
            makespan
        };

        Schedule { start, end, makespan, steady_step_s, busy }
    }
}

/// Map a refactored task kind back onto the v1 enum (link kinds never
/// appear in single-device plans — asserted by the caller).
fn v1_kind(kind: TaskKind) -> reference_v1::TaskKind {
    match kind {
        TaskKind::Upload => reference_v1::TaskKind::Upload,
        TaskKind::Compute => reference_v1::TaskKind::Compute,
        TaskKind::Offload => reference_v1::TaskKind::Offload,
        TaskKind::Update => reference_v1::TaskKind::Update,
        TaskKind::DiskRead => reference_v1::TaskKind::DiskRead,
        TaskKind::DiskWrite => reference_v1::TaskKind::DiskWrite,
        k => panic!("link task {k:?} in a single-device plan"),
    }
}

fn v1_stream_kind(s: reference_v1::Stream) -> StreamKind {
    match s {
        reference_v1::Stream::Upload => StreamKind::Upload,
        reference_v1::Stream::Compute => StreamKind::Compute,
        reference_v1::Stream::Offload => StreamKind::Offload,
        reference_v1::Stream::DiskRead => StreamKind::DiskRead,
        reference_v1::Stream::DiskWrite => StreamKind::DiskWrite,
    }
}

fn assert_plans_identical(new: &[zo2::sched::Task], old: &[reference_v1::Task], what: &str) {
    assert_eq!(new.len(), old.len(), "{what}: task count");
    for (n, o) in new.iter().zip(old) {
        assert_eq!(n.id, o.id, "{what}: id");
        assert_eq!(n.step, o.step, "{what}: task {} step", n.id);
        assert_eq!(n.module, o.module, "{what}: task {} module", n.id);
        assert_eq!(v1_kind(n.kind), o.kind, "{what}: task {} kind", n.id);
        assert_eq!(n.device(), DeviceId(0), "{what}: task {} off device 0", n.id);
        assert_eq!(
            n.stream.kind,
            v1_stream_kind(o.stream),
            "{what}: task {} stream",
            n.id
        );
        assert_eq!(n.deps, o.deps, "{what}: task {} deps", n.id);
        assert!(
            n.extra_latency == o.extra_latency,
            "{what}: task {} extra latency",
            n.id
        );
    }
}

fn assert_schedules_identical(
    new: &zo2::sched::Schedule,
    old: &reference_v1::Schedule,
    what: &str,
) {
    // Bitwise: the refactor may not perturb a single f64.
    for (i, (a, b)) in new.start.iter().zip(&old.start).enumerate() {
        assert!(a.to_bits() == b.to_bits(), "{what}: start[{i}] {a} vs {b}");
    }
    for (i, (a, b)) in new.end.iter().zip(&old.end).enumerate() {
        assert!(a.to_bits() == b.to_bits(), "{what}: end[{i}] {a} vs {b}");
    }
    assert!(new.makespan.to_bits() == old.makespan.to_bits(), "{what}: makespan");
    assert!(
        new.steady_step_s.to_bits() == old.steady_step_s.to_bits(),
        "{what}: steady step"
    );
    for name in ["upload", "compute", "offload", "disk_read", "disk_write"] {
        assert!(
            new.busy_of(name).to_bits() == old.busy_of(name).to_bits(),
            "{what}: busy[{name}] {} vs {}",
            new.busy_of(name),
            old.busy_of(name)
        );
    }
    assert_eq!(new.bottleneck(), old.bottleneck(), "{what}: bottleneck");
}

struct RandCosts {
    up: f64,
    off: f64,
    comp: f64,
    upd: f64,
    read: f64,
    write: f64,
    host: f64,
}

impl CostProvider for RandCosts {
    fn upload_s(&self) -> f64 {
        self.up
    }
    fn offload_s(&self) -> f64 {
        self.off
    }
    fn compute_s(&self, m: Module) -> f64 {
        self.comp * if m == Module::Embed { 0.3 } else { 1.0 }
    }
    fn update_s(&self) -> f64 {
        self.upd
    }
    fn host_decode_s(&self) -> f64 {
        self.host
    }
    fn host_encode_s(&self) -> f64 {
        self.host
    }
    fn disk_read_s(&self) -> f64 {
        self.read
    }
    fn disk_read_bw_s(&self) -> f64 {
        self.read * 0.6
    }
    fn disk_write_s(&self) -> f64 {
        self.write
    }
}

fn rand_case(rng: &mut GaussianRng) -> (usize, usize, RandCosts, Policy) {
    let n_blocks = 1 + rng.next_below(12) as usize;
    let steps = 1 + rng.next_below(4) as usize;
    let costs = RandCosts {
        up: 0.01 + rng.next_uniform() * 2.0,
        off: 0.01 + rng.next_uniform() * 2.0,
        comp: 0.01 + rng.next_uniform() * 4.0,
        upd: 0.01 + rng.next_uniform() * 0.5,
        read: 0.01 + rng.next_uniform() * 3.0,
        write: 0.01 + rng.next_uniform() * 3.0,
        host: rng.next_uniform() * 0.5,
    };
    let three = rng.next_below(2) == 0;
    // spill_placement stays Trailing: that IS the pre-refactor semantics
    // (interleaved placement is new behaviour with no v1 counterpart).
    let policy = Policy {
        overlap: rng.next_below(4) != 0,
        reusable_mem: rng.next_below(2) == 0,
        efficient_update: rng.next_below(2) == 0,
        slots: 1 + rng.next_below(4) as usize,
        tiering: if three { Tiering::ThreeTier } else { Tiering::TwoTier },
        spilled: if three { rng.next_below(1 + n_blocks as u64) as usize } else { 0 },
        dram_slots: 1 + rng.next_below(4) as usize,
        disk_batch: 1 + rng.next_below(4) as usize,
        ..Policy::default()
    };
    (n_blocks, steps, costs, policy)
}

#[test]
fn refactored_plan_is_byte_identical_to_v1_across_random_cases() {
    let mut rng = GaussianRng::new(0x60_1D, 0);
    for case in 0..200 {
        let (n, steps, costs, policy) = rand_case(&mut rng);
        let new_plan = build_plan(n, steps, policy);
        let old_plan = reference_v1::build_plan(n, steps, policy);
        assert_plans_identical(&new_plan, &old_plan, &format!("case {case} ({policy:?})"));

        let (new_sched, _) = simulate(&new_plan, &costs, policy);
        let old_sched = reference_v1::simulate(&old_plan, &costs, policy);
        assert_schedules_identical(&new_sched, &old_sched, &format!("case {case}"));
    }
}

#[test]
fn paper_scale_cost_breakdown_matches_v1() {
    // The acceptance check behind `simulate --devices 1`: same schedule,
    // same cost breakdown, same bottleneck diagnosis as before the
    // refactor, on the real calibrated cost model at paper scale.
    let hw = Hardware::a100_pcie4();
    let cases = [
        ("OPT-13B", Codec::F32, ComputeMode::Fp32, Policy::default()),
        ("OPT-13B", Codec::Fp16, ComputeMode::Fp16, Policy::default()),
        ("OPT-13B", Codec::F32, ComputeMode::Fp32, Policy::naive()),
        ("OPT-175B", Codec::Fp16, ComputeMode::Fp16, Policy::three_tier(70, 4)),
        (
            "OPT-175B",
            Codec::Fp16,
            ComputeMode::Fp16,
            Policy { disk_batch: 4, ..Policy::three_tier(70, 4) },
        ),
    ];
    for (name, wire, compute, policy) in cases {
        let wl = Workload {
            shape: opt_by_name(name).unwrap(),
            batch: 1,
            seq: 2048,
            wire,
            compute,
        };
        let costs = SimCost::new(&hw, &wl);
        let new_plan = build_plan(wl.shape.n_layers, 4, policy);
        let old_plan = reference_v1::build_plan(wl.shape.n_layers, 4, policy);
        assert_plans_identical(&new_plan, &old_plan, name);
        let (new_sched, _) = simulate(&new_plan, &costs, policy);
        let old_sched = reference_v1::simulate(&old_plan, &costs, policy);
        assert_schedules_identical(&new_sched, &old_sched, name);
    }
}
