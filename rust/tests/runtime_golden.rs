//! Runtime↔python golden test: replay the input/output vectors dumped by
//! `compile/aot.py --goldens` through the rust PJRT path and require
//! bit-exact agreement.  This pins the whole interchange: HLO text parse,
//! compile, literal marshalling, tuple decomposition.

use std::path::Path;

use zo2::runtime::{lit_f32, lit_i32, lit_scalar, Runtime};
use zo2::util::json::Json;

fn read_f32(path: &Path) -> Vec<f32> {
    let bytes = std::fs::read(path).unwrap();
    bytes.chunks_exact(4).map(|c| f32::from_le_bytes(c.try_into().unwrap())).collect()
}

fn read_i32(path: &Path) -> Vec<i32> {
    let bytes = std::fs::read(path).unwrap();
    bytes.chunks_exact(4).map(|c| i32::from_le_bytes(c.try_into().unwrap())).collect()
}

fn read_u32(path: &Path) -> Vec<u32> {
    let bytes = std::fs::read(path).unwrap();
    bytes.chunks_exact(4).map(|c| u32::from_le_bytes(c.try_into().unwrap())).collect()
}

#[test]
fn golden_replay_bit_exact() {
    let dir = zo2::artifacts_dir().join("tiny");
    let gdir = dir.join("golden");
    if !zo2::artifacts_available("tiny") || !gdir.is_dir() {
        eprintln!(
            "SKIP golden_replay_bit_exact: no golden bundle at {} (run `make artifacts` \
             or set $ZO2_ARTIFACTS)",
            gdir.display()
        );
        return;
    }
    let rt = Runtime::load(&dir).unwrap();
    rt.manifest().validate().unwrap();

    let index = Json::parse(&std::fs::read_to_string(gdir.join("index.json")).unwrap()).unwrap();
    let cases = index.get("cases").unwrap().as_arr().unwrap();
    assert!(cases.len() >= 5, "expected several golden cases");

    for case in cases {
        let exe = case.get("exe").unwrap().as_str().unwrap();
        let mut inputs = Vec::new();
        for meta in case.get("inputs").unwrap().as_arr().unwrap() {
            let file = gdir.join(meta.get("file").unwrap().as_str().unwrap());
            let shape: Vec<i64> = meta
                .get("shape").unwrap().as_arr().unwrap()
                .iter().map(|s| s.as_usize().unwrap() as i64).collect();
            let dtype = meta.get("dtype").unwrap().as_str().unwrap();
            let lit = match (dtype, shape.is_empty()) {
                ("f32", true) => lit_scalar(read_f32(&file)[0]),
                ("f32", false) => lit_f32(&read_f32(&file), &shape).unwrap(),
                ("i32", false) => lit_i32(&read_i32(&file), &shape).unwrap(),
                ("u32", false) => {
                    let v = read_u32(&file);
                    assert_eq!(v.len(), 2, "keys are u32[2]");
                    zo2::runtime::lit_key([v[0], v[1]]).unwrap()
                }
                _ => panic!("unsupported golden dtype {dtype}"),
            };
            inputs.push(lit);
        }
        let outs = rt.run(exe, &inputs).unwrap();
        let metas = case.get("outputs").unwrap().as_arr().unwrap();
        assert_eq!(outs.len(), metas.len(), "{exe}: output arity");
        for (i, (got, meta)) in outs.iter().zip(metas).enumerate() {
            let want = read_f32(&gdir.join(meta.get("file").unwrap().as_str().unwrap()));
            let got = got.to_vec::<f32>().unwrap();
            assert_eq!(got.len(), want.len(), "{exe}: output length");
            // The goldens were produced by jaxlib's XLA (>= 0.8); the rust
            // side compiles the same HLO with xla_extension 0.5.1.  Different
            // XLA versions fuse/reorder float reductions differently, so the
            // comparison is tolerance-based (tight), not bit-exact.  The
            // bit-exactness claims of the paper (MeZO == ZO2) are *within*
            // the rust runtime and covered by tests/parity.rs.
            let mut max_abs = 0f32;
            let mut max_rel = 0f32;
            for (a, b) in got.iter().zip(&want) {
                let d = (a - b).abs();
                max_abs = max_abs.max(d);
                if b.abs() > 1e-3 {
                    max_rel = max_rel.max(d / b.abs());
                }
            }
            assert!(
                max_abs < 1e-3 && max_rel < 1e-3,
                "{exe} out{i}: max_abs={max_abs:e} max_rel={max_rel:e}"
            );
        }
    }
}
