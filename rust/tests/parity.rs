//! The paper's central correctness claim (§5.1, Table 3): ZO2 is
//! **bit-identical** to MeZO — offloading, deferred updates, compression
//! scheduling and thread overlap change *when* and *where* math happens,
//! never *what* is computed.
//!
//! Requires `make artifacts` (tiny config).

use zo2::precision::Codec;
use zo2::runtime::Runtime;
use zo2::zo::{MezoEngine, RunMode, Zo2Engine, Zo2Options, ZoConfig};

/// Skip (with a message) when the PJRT artifacts are absent: parity runs
/// real executions and needs `make artifacts` (or `$ZO2_ARTIFACTS`).
macro_rules! require_artifacts {
    () => {
        if !zo2::artifacts_available("tiny") {
            eprintln!(
                "SKIP {}: no PJRT artifacts for config `tiny` (run `make artifacts` \
                 or set $ZO2_ARTIFACTS)",
                module_path!()
            );
            return;
        }
    };
}

const STEPS: usize = 6;

fn batches(rt: &Runtime, seed: u64) -> Vec<Vec<i32>> {
    let m = rt.manifest();
    let mut corpus = zo2::data::SyntheticCorpus::new(m.config.vocab, seed);
    (0..STEPS).map(|_| corpus.sample(m.config.batch, m.config.seq_len).ids).collect()
}

fn cfg() -> ZoConfig {
    ZoConfig { lr: 1e-3, eps: 1e-3, seed: 1234 }
}

fn run_mezo() -> (Vec<(f32, f32)>, Vec<f32>) {
    let rt = Runtime::load_config("tiny").unwrap();
    let data = batches(&rt, 99);
    let mut e = MezoEngine::new(rt, cfg()).unwrap();
    let mut losses = Vec::new();
    for ids in &data {
        let s = e.train_step(ids).unwrap();
        losses.push((s.loss_plus, s.loss_minus));
    }
    (losses, e.params.to_flat_f32())
}

fn run_zo2(opts: Zo2Options) -> (Vec<(f32, f32)>, Vec<f32>) {
    let rt = Runtime::load_config("tiny").unwrap();
    let data = batches(&rt, 99);
    let mut e = Zo2Engine::new(rt, cfg(), opts).unwrap();
    let mut losses = Vec::new();
    for ids in &data {
        let s = e.train_step(ids).unwrap();
        losses.push((s.loss_plus, s.loss_minus));
    }
    e.flush_updates().unwrap(); // the paper's final zo_update (Fig. 6b)
    (losses, e.params.to_flat_f32())
}

fn assert_bit_equal(a: &[f32], b: &[f32], what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: length");
    let diffs = a.iter().zip(b).filter(|(x, y)| x.to_bits() != y.to_bits()).count();
    assert_eq!(diffs, 0, "{what}: {diffs}/{} values differ bitwise", a.len());
}

#[test]
fn zo2_sequential_is_bit_identical_to_mezo() {
    require_artifacts!();
    let (ml, mp) = run_mezo();
    let (zl, zp) = run_zo2(Zo2Options { run_mode: RunMode::Sequential, ..Default::default() });
    for (i, (a, b)) in ml.iter().zip(&zl).enumerate() {
        assert_eq!(a.0.to_bits(), b.0.to_bits(), "step {i} loss+");
        assert_eq!(a.1.to_bits(), b.1.to_bits(), "step {i} loss-");
    }
    assert_bit_equal(&mp, &zp, "final parameters");
}

#[test]
fn zo2_overlapped_is_bit_identical_to_mezo() {
    require_artifacts!();
    let (ml, mp) = run_mezo();
    let (zl, zp) = run_zo2(Zo2Options { run_mode: RunMode::Overlapped, ..Default::default() });
    for (i, (a, b)) in ml.iter().zip(&zl).enumerate() {
        assert_eq!(a.0.to_bits(), b.0.to_bits(), "step {i} loss+ (threads must not change math)");
        assert_eq!(a.1.to_bits(), b.1.to_bits(), "step {i} loss-");
    }
    assert_bit_equal(&mp, &zp, "final parameters (overlapped)");
}

#[test]
fn non_efficient_update_ablation_same_numerics() {
    require_artifacts!();
    // Fig. 5a ordering (update right after the step) is mathematically the
    // same trajectory — only the transfer schedule differs.
    let (ml, mp) = run_mezo();
    let (zl, zp) = run_zo2(Zo2Options {
        efficient_update: false,
        run_mode: RunMode::Sequential,
        ..Default::default()
    });
    for (a, b) in ml.iter().zip(&zl) {
        assert_eq!(a.0.to_bits(), b.0.to_bits());
    }
    assert_bit_equal(&mp, &zp, "final parameters (non-efficient update)");
}

#[test]
fn amp_compression_stays_in_format_error_band() {
    require_artifacts!();
    // AMP low-bit storage (§5.5) is *not* bit-exact by design; it must stay
    // within the format's quantisation band of the fp32 run.
    let (_, mp) = run_mezo();
    let (_, zp) = run_zo2(Zo2Options {
        wire: Codec::Bf16,
        run_mode: RunMode::Sequential,
        ..Default::default()
    });
    assert_eq!(mp.len(), zp.len());
    // Individual elements can accumulate multi-ulp drift over repeated
    // quantize→train→quantize cycles; the aggregate (relative L2) must stay
    // within a small multiple of bf16's ~0.4% step.
    let (mut d2, mut n2) = (0f64, 0f64);
    for (a, b) in mp.iter().zip(&zp) {
        d2 += ((a - b) as f64).powi(2);
        n2 += (*a as f64).powi(2);
    }
    let rel_l2 = (d2 / n2).sqrt();
    assert!(rel_l2 < 0.02, "bf16 storage rel-L2 drift {rel_l2} beyond band");
    assert!(rel_l2 > 0.0, "bf16 run should differ from fp32 somewhere");
}

#[test]
fn deferred_update_really_is_deferred() {
    require_artifacts!();
    // Before the flush, ZO2's parameters lag MeZO's by exactly the last
    // gradient application; after the flush they coincide.
    let rt = Runtime::load_config("tiny").unwrap();
    let data = batches(&rt, 99);
    let mut e = Zo2Engine::new(rt, cfg(), Zo2Options::default()).unwrap();
    for ids in &data {
        e.train_step(ids).unwrap();
    }
    let before = e.params.to_flat_f32();
    e.flush_updates().unwrap();
    let after = e.params.to_flat_f32();
    assert_ne!(before, after, "flush must apply the pending g_T");
    let (_, mezo_final) = run_mezo();
    assert_bit_equal(&after, &mezo_final, "post-flush parameters");
}
