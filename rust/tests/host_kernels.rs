//! Host-kernel acceptance tests.
//!
//! Always-on half: randomized property sweeps that the fused
//! decode→update→encode kernels are bit-identical to the unfused reference
//! composition for all four codecs, and that every pooled kernel returns
//! identical bytes for 1, 2 and 8 worker threads (the determinism
//! contract: fixed chunk grid + per-chunk counter-offset RNG replay).
//!
//! Also here: negative-path and round-trip coverage for
//! `costmodel::HostKernels::from_bench_json`, the calibration loader over
//! the `BENCH_host_kernels.json` file the bench writes (it landed with
//! only happy-path tests).
//!
//! Real-execution half (needs `make artifacts`): the engine's CPU update
//! site is deterministic across run modes, tiering and host thread counts,
//! and its flush round moves zero bytes over the interconnect.

use std::collections::BTreeMap;

use zo2::costmodel::HostKernels;
use zo2::hostpool::{fused, HostPool, CHUNK_ELEMS};
use zo2::precision::Codec;
use zo2::rng::{GaussianRng, RngState};
use zo2::runtime::Runtime;
use zo2::simd::{self, SimdLevel, SimdMode};
use zo2::util::json::Json;
use zo2::zo::{
    cpu_zo_adamw_update, cpu_zo_sgd_update, AdamHp, AdamState, RunMode, Tiering, UpdateSite,
    ZScratch, Zo2Engine, Zo2Options, ZoConfig,
};

/// Serialises tests that flip the process-wide `--host-simd` /
/// `--disk-uring` switches so each sees the mode it set.  (Correctness
/// never depends on the mode — both paths are bit-identical — this lock
/// only keeps the *intent* of each toggle test meaningful.)
static SWITCH_LOCK: std::sync::Mutex<()> = std::sync::Mutex::new(());

fn switch_guard() -> std::sync::MutexGuard<'static, ()> {
    SWITCH_LOCK.lock().unwrap_or_else(|e| e.into_inner())
}

macro_rules! require_artifacts {
    () => {
        if !zo2::artifacts_available("tiny") {
            eprintln!(
                "SKIP {}: no PJRT artifacts for config `tiny` (run `make artifacts` \
                 or set $ZO2_ARTIFACTS)",
                module_path!()
            );
            return;
        }
    };
}

const CODECS: [Codec; 4] = [Codec::F32, Codec::Bf16, Codec::Fp16, Codec::Fp8E4M3];

fn params(n: usize, seed: u64) -> Vec<f32> {
    let mut xs = vec![0.0f32; n];
    GaussianRng::new(seed, 0).fill_gaussian(&mut xs);
    for x in xs.iter_mut() {
        *x *= 0.02; // parameter-scale, representable in fp8's range
    }
    xs
}

#[test]
fn fused_sgd_matches_reference_composition_randomized() {
    let mut case_rng = GaussianRng::new(404, 0);
    let pool = HostPool::new(4);
    for case in 0..12u64 {
        let n = 1 + case_rng.next_below((3 * CHUNK_ELEMS) as u64) as usize;
        let state = RngState {
            seed: case_rng.next_below(1 << 20),
            stream: case_rng.next_below(64),
            counter: case_rng.next_below(1 << 30),
        };
        let lr = 10f32.powi(-(2 + (case % 4) as i32));
        let g = (case_rng.next_uniform() as f32 - 0.5) * 4.0;
        let xs = params(n, 1000 + case);
        for codec in CODECS {
            let wire0 = codec.encode(&xs);
            // Reference: the three-pass composition through fp32.
            let mut dec = codec.decode(&wire0, n);
            let mut zs = ZScratch::new();
            cpu_zo_sgd_update(&mut dec, state, lr, g, &mut zs);
            let want = codec.encode(&dec);
            // Fused one-pass, pooled.
            let mut got = wire0.clone();
            fused::fused_zo_sgd(codec, &mut got, n, state, lr, g, &pool);
            assert_eq!(got, want, "case {case} {codec:?} n={n}");
        }
    }
}

#[test]
fn every_pooled_kernel_is_identical_across_1_2_8_threads() {
    let n = 2 * CHUNK_ELEMS + 1234;
    let xs = params(n, 9);
    let state = RngState { seed: 3, stream: 5, counter: 11 };
    let hp = AdamHp { lr: 2e-3, weight_decay: 0.02, ..Default::default() };
    for codec in CODECS {
        let wire0 = codec.encode(&xs);
        let mut sgd_outs: Vec<Vec<u8>> = Vec::new();
        let mut adamw_outs: Vec<(Vec<u8>, Vec<f32>, Vec<f32>)> = Vec::new();
        let mut enc_outs: Vec<Vec<u8>> = Vec::new();
        let mut dec_outs: Vec<Vec<u32>> = Vec::new();
        for threads in [1usize, 2, 8] {
            let pool = HostPool::new(threads);
            // fused SGD
            let mut w = wire0.clone();
            fused::fused_zo_sgd(codec, &mut w, n, state, 1e-3, 0.9, &pool);
            sgd_outs.push(w);
            // fused AdamW
            let mut w = wire0.clone();
            let mut st = AdamState::new(n);
            zo2::zo::fused_zo_adamw(&pool, codec, &mut w, &mut st, state, hp, 1.3);
            adamw_outs.push((w, st.m, st.v));
            // pooled encode / decode
            let mut enc = vec![0u8; wire0.len()];
            fused::encode_pooled(codec, &xs, &mut enc, &pool);
            enc_outs.push(enc);
            let mut dec = vec![0.0f32; n];
            fused::decode_pooled(codec, &wire0, &mut dec, &pool);
            dec_outs.push(dec.iter().map(|x| x.to_bits()).collect());
        }
        for i in 1..3 {
            assert_eq!(sgd_outs[0], sgd_outs[i], "{codec:?} sgd threads[{i}]");
            assert_eq!(adamw_outs[0].0, adamw_outs[i].0, "{codec:?} adamw wire threads[{i}]");
            let m_same = adamw_outs[0]
                .1
                .iter()
                .zip(&adamw_outs[i].1)
                .all(|(a, b)| a.to_bits() == b.to_bits());
            let v_same = adamw_outs[0]
                .2
                .iter()
                .zip(&adamw_outs[i].2)
                .all(|(a, b)| a.to_bits() == b.to_bits());
            assert!(m_same && v_same, "{codec:?} adamw moments threads[{i}]");
            assert_eq!(enc_outs[0], enc_outs[i], "{codec:?} encode threads[{i}]");
            assert_eq!(dec_outs[0], dec_outs[i], "{codec:?} decode threads[{i}]");
        }
    }
}

#[test]
fn fused_adamw_composition_over_multiple_steps() {
    // Moments accumulate across steps; the fused wire-domain path must
    // track the decode→scalar-AdamW→encode composition step for step.
    let n = CHUNK_ELEMS + 55;
    let xs = params(n, 21);
    let hp = AdamHp { lr: 1e-3, ..Default::default() };
    let pool = HostPool::new(8);
    for codec in [Codec::Bf16, Codec::Fp16] {
        let mut ref_wire = codec.encode(&xs);
        let mut st_ref = AdamState::new(n);
        let mut fused_wire = ref_wire.clone();
        let mut st_fused = AdamState::new(n);
        let mut zs = ZScratch::new();
        for step in 0..4u64 {
            let state = RngState { seed: 2, stream: step, counter: 0 };
            let mut dec = codec.decode(&ref_wire, n);
            cpu_zo_adamw_update(&mut dec, &mut st_ref, state, hp, 0.6, &mut zs);
            ref_wire = codec.encode(&dec);
            zo2::zo::fused_zo_adamw(&pool, codec, &mut fused_wire, &mut st_fused, state, hp, 0.6);
            assert_eq!(fused_wire, ref_wire, "{codec:?} step {step}");
        }
        assert_eq!(st_ref.t, st_fused.t);
    }
}

// --- SIMD-vs-scalar bit-equality (tentpole contract) ---------------------------

#[test]
fn simd_decode_is_bit_identical_for_every_wire_pattern() {
    // Exhaustive: all 65536 fp16 / bf16 wire patterns and all 256 fp8
    // patterns — every NaN, infinity, denormal and normal lane — decoded
    // through the explicit-level API.  On CPUs without AVX2 the vector
    // level degrades to scalar and the test is trivially green.
    for codec in [Codec::Fp16, Codec::Bf16] {
        let mut src = Vec::with_capacity(2 * 65536);
        for p in 0..=u16::MAX {
            src.extend_from_slice(&p.to_le_bytes());
        }
        let mut scalar = vec![0.0f32; 65536];
        let mut vector = vec![0.0f32; 65536];
        codec.decode_chunk_with(SimdLevel::Scalar, &src, &mut scalar);
        codec.decode_chunk_with(SimdLevel::Avx2, &src, &mut vector);
        for (p, (a, b)) in scalar.iter().zip(&vector).enumerate() {
            assert_eq!(a.to_bits(), b.to_bits(), "{codec:?} wire pattern {p:#06x}");
        }
    }
    let src: Vec<u8> = (0..=u8::MAX).collect();
    let mut scalar = vec![0.0f32; 256];
    let mut vector = vec![0.0f32; 256];
    Codec::Fp8E4M3.decode_chunk_with(SimdLevel::Scalar, &src, &mut scalar);
    Codec::Fp8E4M3.decode_chunk_with(SimdLevel::Avx2, &src, &mut vector);
    for (p, (a, b)) in scalar.iter().zip(&vector).enumerate() {
        assert_eq!(a.to_bits(), b.to_bits(), "fp8 wire pattern {p:#04x}");
    }
}

#[test]
fn simd_encode_is_bit_identical_over_boundary_and_random_values() {
    // Encode inputs: every f32 whose high 16 bits take each of the 65536
    // patterns (all signs/exponents, incl. NaN/inf/denormal), crossed with
    // low-bit variants straddling the round-to-nearest-even boundaries;
    // plus every exactly-representable fp16 value and a random-bits sweep.
    let mut vals: Vec<f32> = Vec::new();
    for p in 0..=u16::MAX {
        let hi = (p as u32) << 16;
        for lo in [0u32, 1, 0x7FFF, 0x8000, 0x8001, 0xFFFF] {
            vals.push(f32::from_bits(hi | lo));
        }
    }
    {
        let mut wire = Vec::with_capacity(2 * 65536);
        for p in 0..=u16::MAX {
            wire.extend_from_slice(&p.to_le_bytes());
        }
        let mut dec = vec![0.0f32; 65536];
        Codec::Fp16.decode_chunk_with(SimdLevel::Scalar, &wire, &mut dec);
        vals.extend_from_slice(&dec);
    }
    let mut rng = GaussianRng::new(515, 0);
    for _ in 0..(1 << 18) {
        vals.push(f32::from_bits(rng.next_below(1u64 << 32) as u32));
    }
    // Odd length: the vector kernels' scalar tails are exercised too.
    vals.push(0.5);

    for codec in CODECS {
        let mut scalar = vec![0u8; vals.len() * codec.bytes_per_el()];
        let mut vector = scalar.clone();
        codec.encode_chunk_with(SimdLevel::Scalar, &vals, &mut scalar);
        codec.encode_chunk_with(SimdLevel::Avx2, &vals, &mut vector);
        if let Some(i) = (0..scalar.len()).find(|&i| scalar[i] != vector[i]) {
            let el = i / codec.bytes_per_el();
            panic!(
                "{codec:?}: encode diverges at element {el} (input bits {:#010x}): \
                 scalar byte {:#04x} vs simd {:#04x}",
                vals[el].to_bits(),
                scalar[i],
                vector[i]
            );
        }
    }
}

#[test]
fn gaussian_fill_is_bit_identical_simd_vs_scalar() {
    let _g = switch_guard();
    // Lengths straddling the 8-lane width (odd tails, sub-lane buffers)
    // and counters deep into the stream (per-chunk replay offsets).
    for n in [1usize, 2, 7, 8, 9, 31, 1000, CHUNK_ELEMS + 3] {
        for counter in [0u64, 5, 1 << 33] {
            let state = RngState { seed: 77, stream: 3, counter };
            let mut a = vec![0.0f32; n];
            let mut b = vec![0.0f32; n];
            simd::set_mode(SimdMode::Off);
            let mut r = GaussianRng::from_state(state);
            r.fill_gaussian(&mut a);
            let end_scalar = r.state();
            simd::set_mode(SimdMode::Auto);
            let mut r = GaussianRng::from_state(state);
            r.fill_gaussian(&mut b);
            let end_simd = r.state();
            simd::set_mode(SimdMode::Auto);
            for (i, (x, y)) in a.iter().zip(&b).enumerate() {
                assert_eq!(x.to_bits(), y.to_bits(), "n={n} counter={counter} elem {i}");
            }
            // The post-fill counter must agree so subsequent draws align.
            assert_eq!(end_scalar.counter, end_simd.counter, "n={n} counter={counter}");
        }
    }
}

#[test]
fn fused_kernels_are_invariant_across_simd_pin_and_thread_grid() {
    let _g = switch_guard();
    let n = 2 * CHUNK_ELEMS + 777;
    let xs = params(n, 31);
    let state = RngState { seed: 6, stream: 2, counter: 9 };
    let hp = AdamHp { lr: 1e-3, weight_decay: 0.01, ..Default::default() };
    for codec in CODECS {
        let wire0 = codec.encode(&xs);
        // Reference: scalar dispatch, 1 unpinned thread.
        simd::set_mode(SimdMode::Off);
        let mut sgd_ref = wire0.clone();
        fused::fused_zo_sgd(codec, &mut sgd_ref, n, state, 1e-3, 0.7, &HostPool::new(1));
        let mut adamw_ref = wire0.clone();
        let mut st_ref = AdamState::new(n);
        zo2::zo::fused_zo_adamw(
            &HostPool::new(1),
            codec,
            &mut adamw_ref,
            &mut st_ref,
            state,
            hp,
            1.1,
        );
        for mode in [SimdMode::Off, SimdMode::Auto] {
            for pin in [false, true] {
                for threads in [1usize, 2, 8] {
                    simd::set_mode(mode);
                    let pool = HostPool::with_opts(threads, pin);
                    let tag = format!("{codec:?} {mode:?} pin={pin} threads={threads}");
                    let mut w = wire0.clone();
                    fused::fused_zo_sgd(codec, &mut w, n, state, 1e-3, 0.7, &pool);
                    assert_eq!(w, sgd_ref, "{tag}: sgd");
                    let mut w = wire0.clone();
                    let mut st = AdamState::new(n);
                    zo2::zo::fused_zo_adamw(&pool, codec, &mut w, &mut st, state, hp, 1.1);
                    assert_eq!(w, adamw_ref, "{tag}: adamw wire");
                    assert!(
                        st.m.iter().zip(&st_ref.m).all(|(a, b)| a.to_bits() == b.to_bits())
                            && st.v.iter().zip(&st_ref.v).all(|(a, b)| a.to_bits() == b.to_bits()),
                        "{tag}: adamw moments"
                    );
                }
            }
        }
    }
    simd::set_mode(SimdMode::Auto);
}

// --- calibration-loader coverage (costmodel::HostKernels) ----------------------

/// Fresh temp dir per test so parallel test binaries never collide.
fn loader_tmp_dir(tag: &str) -> std::path::PathBuf {
    let dir = std::env::temp_dir().join(format!("zo2_hk_loader_{tag}_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

#[test]
fn bench_json_loader_round_trips_a_bench_shaped_fixture() {
    // Write a fixture with exactly the shape `table_host_kernels` emits —
    // a `rows` array plus the `calibration` block — via the same Json
    // writer, then load it back and check every rate lands bit-for-bit.
    let dir = loader_tmp_dir("roundtrip");
    let path = dir.join("BENCH_host_kernels.json");
    let rates = [
        (Codec::F32, 11.5e9),
        (Codec::Bf16, 4.25e9),
        (Codec::Fp16, 3.75e9),
        (Codec::Fp8E4M3, 2.5e9),
    ];
    let mut calib = BTreeMap::new();
    for (codec, rate) in rates {
        calib.insert(format!("{}_bytes_per_s_per_thread", codec.name()), Json::Num(rate));
    }
    let mut row = BTreeMap::new();
    row.insert("codec".to_string(), Json::Str("fp32".to_string()));
    row.insert("scalar_gbps".to_string(), Json::Num(9.0));
    let mut doc = BTreeMap::new();
    doc.insert("bench".to_string(), Json::Str("host_kernels".to_string()));
    doc.insert("elems".to_string(), Json::Num(65536.0));
    doc.insert("rows".to_string(), Json::Arr(vec![Json::Obj(row)]));
    doc.insert("calibration".to_string(), Json::Obj(calib));
    std::fs::write(&path, Json::Obj(doc).to_string_pretty()).unwrap();

    let hk = HostKernels::from_bench_json(path.to_str().unwrap()).unwrap();
    assert_eq!(hk.fp32_bytes_per_s.to_bits(), 11.5e9f64.to_bits());
    assert_eq!(hk.bf16_bytes_per_s.to_bits(), 4.25e9f64.to_bits());
    assert_eq!(hk.fp16_bytes_per_s.to_bits(), 3.75e9f64.to_bits());
    assert_eq!(hk.fp8_bytes_per_s.to_bits(), 2.5e9f64.to_bits());
    // The thread count is a deployment choice, not a calibration output.
    assert_eq!(hk.threads, HostKernels::calibrated().threads);
    // The loaded rates drive the cost term: pass_s follows the file.
    let want = (1_000_000usize * 4) as f64 / (hk.threads as f64 * 3.75e9);
    assert_eq!(hk.pass_s(Codec::Fp16, 1_000_000).to_bits(), want.to_bits());
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn bench_json_loader_rejects_malformed_and_incomplete_files() {
    let dir = loader_tmp_dir("negative");
    let path = dir.join("bad.json");
    let load = |text: &str| {
        std::fs::write(&path, text).unwrap();
        HostKernels::from_bench_json(path.to_str().unwrap())
    };

    // Malformed JSON: truncated, trailing garbage, not-JSON-at-all.
    assert!(load("{\"calibration\": {").is_err(), "truncated object must fail");
    assert!(load("{} trailing").is_err(), "trailing characters must fail");
    assert!(load("not json").is_err(), "non-JSON must fail");
    // Structurally valid but missing the calibration block entirely.
    assert!(load("{\"bench\": \"host_kernels\"}").is_err(), "missing calibration");
    // Calibration present but one codec's key missing.
    assert!(
        load(
            r#"{"calibration": {
                "fp32_bytes_per_s_per_thread": 1e9,
                "bf16_bytes_per_s_per_thread": 1e9,
                "fp16_bytes_per_s_per_thread": 1e9}}"#
        )
        .is_err(),
        "missing fp8 key"
    );
    // A rate that is not a number.
    assert!(
        load(
            r#"{"calibration": {
                "fp32_bytes_per_s_per_thread": "fast",
                "bf16_bytes_per_s_per_thread": 1e9,
                "fp16_bytes_per_s_per_thread": 1e9,
                "fp8_bytes_per_s_per_thread": 1e9}}"#
        )
        .is_err(),
        "non-numeric rate"
    );
    // Zero and negative rates would divide-by-zero the cost term: loud error.
    for bad in ["0", "-3e9"] {
        assert!(
            load(&format!(
                r#"{{"calibration": {{
                    "fp32_bytes_per_s_per_thread": {bad},
                    "bf16_bytes_per_s_per_thread": 1e9,
                    "fp16_bytes_per_s_per_thread": 1e9,
                    "fp8_bytes_per_s_per_thread": 1e9}}}}"#
            ))
            .is_err(),
            "non-positive rate {bad} must fail"
        );
    }
    // Calibration that is not an object.
    assert!(load(r#"{"calibration": 42}"#).is_err(), "calibration must be an object");
    // And a missing file names the path in its error.
    let missing = dir.join("nope.json");
    let err = HostKernels::from_bench_json(missing.to_str().unwrap()).unwrap_err().to_string();
    assert!(err.contains("nope.json"), "error should name the path: {err}");
    let _ = std::fs::remove_dir_all(&dir);
}

// --- real-execution half -------------------------------------------------------

const STEPS: usize = 4;

fn run_engine(opts: Zo2Options) -> (Vec<(f32, f32)>, Vec<f32>) {
    let rt = Runtime::load_config("tiny").unwrap();
    let m = rt.manifest();
    let mut corpus = zo2::data::SyntheticCorpus::new(m.config.vocab, 13);
    let data: Vec<Vec<i32>> =
        (0..STEPS).map(|_| corpus.sample(m.config.batch, m.config.seq_len).ids).collect();
    let mut e = Zo2Engine::new(rt, ZoConfig { lr: 1e-3, eps: 1e-3, seed: 33 }, opts).unwrap();
    let mut losses = Vec::new();
    for ids in &data {
        let s = e.train_step(ids).unwrap();
        losses.push((s.loss_plus, s.loss_minus));
    }
    e.flush_updates().unwrap();
    (losses, e.flat_params().unwrap())
}

fn assert_runs_equal(a: &(Vec<(f32, f32)>, Vec<f32>), b: &(Vec<(f32, f32)>, Vec<f32>), what: &str) {
    for (i, (x, y)) in a.0.iter().zip(&b.0).enumerate() {
        assert_eq!(x.0.to_bits(), y.0.to_bits(), "{what}: step {i} loss+");
        assert_eq!(x.1.to_bits(), y.1.to_bits(), "{what}: step {i} loss-");
    }
    assert_eq!(a.1.len(), b.1.len(), "{what}: param count");
    let diffs = a.1.iter().zip(&b.1).filter(|(x, y)| x.to_bits() != y.to_bits()).count();
    assert_eq!(diffs, 0, "{what}: {diffs} params differ bitwise");
}

#[test]
fn cpu_update_site_is_deterministic_across_modes_tiers_and_threads() {
    require_artifacts!();
    let base = Zo2Options { update_site: UpdateSite::Cpu, ..Zo2Options::default() };
    let reference = run_engine(Zo2Options { host_threads: 1, ..base });
    // Thread counts never change the trajectory.
    for host_threads in [2usize, 8] {
        let got = run_engine(Zo2Options { host_threads, ..base });
        assert_runs_equal(&reference, &got, &format!("{host_threads} host threads"));
    }
    // Sequential and overlapped schedules agree.
    let seq = run_engine(Zo2Options { run_mode: RunMode::Sequential, ..base });
    assert_runs_equal(&reference, &seq, "sequential vs overlapped");
    // The disk tier does not change the math at the CPU site either.
    let spilled = run_engine(Zo2Options {
        tiering: Tiering::ThreeTier,
        dram_resident_blocks: 0,
        dram_slots: 2,
        ..base
    });
    assert_runs_equal(&reference, &spilled, "three-tier");
    // Neither do the host-kernel switches: SIMD dispatch off, NUMA-pinned
    // pool workers, and the io_uring batched-read path vs its positioned
    // read fallback (exercised through the spilled three-tier config).
    {
        let _g = switch_guard();
        simd::set_mode(SimdMode::Off);
        let simd_off = run_engine(base);
        simd::set_mode(SimdMode::Auto);
        assert_runs_equal(&reference, &simd_off, "--host-simd off");
    }
    let pinned = run_engine(Zo2Options { host_pin: true, host_threads: 4, ..base });
    assert_runs_equal(&reference, &pinned, "--host-pin");
    {
        let _g = switch_guard();
        let spilled_opts = Zo2Options {
            tiering: Tiering::ThreeTier,
            dram_resident_blocks: 0,
            dram_slots: 2,
            host_pin: true,
            ..base
        };
        zo2::memory::disk::set_disk_uring(false);
        let uring_off = run_engine(spilled_opts);
        zo2::memory::disk::set_disk_uring(true);
        let uring_auto = run_engine(spilled_opts);
        assert_runs_equal(&reference, &uring_off, "three-tier pinned, --disk-uring off");
        assert_runs_equal(&reference, &uring_auto, "three-tier pinned, --disk-uring auto");
    }
    // And the CPU site is a *different* deterministic trajectory than the
    // device site (host RNG draw; documented in cpu_optim).
    let device = run_engine(Zo2Options::default());
    let any_diff = reference.1.iter().zip(&device.1).any(|(x, y)| x.to_bits() != y.to_bits());
    assert!(any_diff, "CPU site must be its own trajectory, not the device one");
}

#[test]
fn cpu_update_site_flush_moves_no_bytes() {
    require_artifacts!();
    let rt = Runtime::load_config("tiny").unwrap();
    let m = rt.manifest();
    let n_blocks = m.config.n_layers as u64;
    let wire = (m.block.size * 4) as u64;
    let mut corpus = zo2::data::SyntheticCorpus::new(m.config.vocab, 13);
    let ids = corpus.sample(m.config.batch, m.config.seq_len).ids;
    let mut e = Zo2Engine::new(
        rt,
        ZoConfig::default(),
        Zo2Options { update_site: UpdateSite::Cpu, ..Zo2Options::default() },
    )
    .unwrap();
    let steps = 3u64;
    for _ in 0..steps {
        e.train_step(&ids).unwrap();
    }
    let before = e.transfers.lock().unwrap().total_bytes();
    assert_eq!(before, steps * n_blocks * wire * 2, "one h2d+d2h per block per step");
    // Flushing the pending update runs entirely on the host pool.
    e.flush_updates().unwrap();
    let after = e.transfers.lock().unwrap().total_bytes();
    assert_eq!(after, before, "CPU-site flush must not touch the interconnect");
}
