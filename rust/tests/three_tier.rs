//! Three-tier (HBM / DDR / NVMe) acceptance tests.
//!
//! Real-execution half (needs `make artifacts`): moving block master copies
//! to the disk tier must not change the math — two-tier and three-tier
//! engines produce bit-identical loss trajectories and final parameters
//! (the §5.1 RNG-replay argument extended one tier down).
//!
//! Analytic half (always runs): an OPT-175B fp16 config on an
//! 18 GB-HBM / 64 GB-DRAM workstation fits every tier budget, and with
//! ample DRAM the three-tier schedule's throughput is within 25% of the
//! two-tier schedule (it degenerates to it).

use zo2::costmodel::{
    plan_three_tier, two_tier_dram_bytes, ComputeMode, Hardware, MemoryBudget, SimCost, Workload,
};
use zo2::model::opt_by_name;
use zo2::precision::Codec;
use zo2::runtime::Runtime;
use zo2::sched::{build_plan, simulate, Policy, SpillPlacement, Tiering};
use zo2::zo::{RunMode, Zo2Engine, Zo2Options, ZoConfig};

macro_rules! require_artifacts {
    () => {
        if !zo2::artifacts_available("tiny") {
            eprintln!(
                "SKIP {}: no PJRT artifacts for config `tiny` (run `make artifacts` \
                 or set $ZO2_ARTIFACTS)",
                module_path!()
            );
            return;
        }
    };
}

const STEPS: usize = 5;

fn cfg() -> ZoConfig {
    ZoConfig { lr: 1e-3, eps: 1e-3, seed: 77 }
}

fn run(opts: Zo2Options) -> (Vec<(f32, f32)>, Vec<f32>) {
    let rt = Runtime::load_config("tiny").unwrap();
    let m = rt.manifest();
    let mut corpus = zo2::data::SyntheticCorpus::new(m.config.vocab, 31);
    let data: Vec<Vec<i32>> =
        (0..STEPS).map(|_| corpus.sample(m.config.batch, m.config.seq_len).ids).collect();
    let mut e = Zo2Engine::new(rt, cfg(), opts).unwrap();
    let mut losses = Vec::new();
    for ids in &data {
        let s = e.train_step(ids).unwrap();
        losses.push((s.loss_plus, s.loss_minus));
    }
    e.flush_updates().unwrap();
    (losses, e.flat_params().unwrap())
}

fn assert_bit_equal(a: &[(f32, f32)], pa: &[f32], b: &[(f32, f32)], pb: &[f32], what: &str) {
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        assert_eq!(x.0.to_bits(), y.0.to_bits(), "{what}: step {i} loss+");
        assert_eq!(x.1.to_bits(), y.1.to_bits(), "{what}: step {i} loss-");
    }
    assert_eq!(pa.len(), pb.len(), "{what}: param count");
    let diffs = pa.iter().zip(pb).filter(|(x, y)| x.to_bits() != y.to_bits()).count();
    assert_eq!(diffs, 0, "{what}: {diffs}/{} params differ bitwise", pa.len());
}

#[test]
fn three_tier_is_bit_identical_to_two_tier() {
    require_artifacts!();
    let (l2, p2) = run(Zo2Options::default());
    for (resident, label) in [(0usize, "all spilled"), (1, "partial spill")] {
        for mode in [RunMode::Sequential, RunMode::Overlapped] {
            let (l3, p3) = run(Zo2Options {
                tiering: Tiering::ThreeTier,
                dram_resident_blocks: resident,
                dram_slots: 2,
                run_mode: mode,
                ..Zo2Options::default()
            });
            assert_bit_equal(&l2, &p2, &l3, &p3, &format!("{label} / {mode:?}"));
        }
    }
}

#[test]
fn interleaved_spill_placement_is_bit_identical_too() {
    require_artifacts!();
    let (l2, p2) = run(Zo2Options::default());
    let (l3, p3) = run(Zo2Options {
        tiering: Tiering::ThreeTier,
        dram_resident_blocks: 1,
        dram_slots: 2,
        spill_placement: SpillPlacement::Interleaved,
        ..Zo2Options::default()
    });
    assert_bit_equal(&l2, &p2, &l3, &p3, "interleaved spill placement");
}

#[test]
fn interleaved_engine_spills_the_planner_spill_set() {
    require_artifacts!();
    let rt = Runtime::load_config("tiny").unwrap();
    let n_blocks = rt.manifest().config.n_layers;
    if n_blocks < 2 {
        eprintln!("SKIP: config too small to compare placements");
        return;
    }
    let e = Zo2Engine::new(
        rt,
        cfg(),
        Zo2Options {
            tiering: Tiering::ThreeTier,
            dram_resident_blocks: n_blocks - 1,
            dram_slots: 1,
            spill_placement: SpillPlacement::Interleaved,
            ..Zo2Options::default()
        },
    )
    .unwrap();
    assert_eq!(e.spilled_blocks(), 1);
    for i in 0..n_blocks {
        assert_eq!(
            e.is_spilled(i),
            zo2::sched::is_spilled_block(i, n_blocks, 1, SpillPlacement::Interleaved),
            "block {i}"
        );
    }
}

#[test]
fn three_tier_disk_traffic_and_window_are_accounted() {
    require_artifacts!();
    let rt = Runtime::load_config("tiny").unwrap();
    let m = rt.manifest();
    let n_blocks = m.config.n_layers;
    let block_bytes = (m.block.size * 4) as u64;
    let mut corpus = zo2::data::SyntheticCorpus::new(m.config.vocab, 31);
    let ids = corpus.sample(m.config.batch, m.config.seq_len).ids;
    let mut e = Zo2Engine::new(
        rt,
        cfg(),
        Zo2Options {
            tiering: Tiering::ThreeTier,
            dram_resident_blocks: 0,
            dram_slots: 2,
            run_mode: RunMode::Overlapped,
            ..Zo2Options::default()
        },
    )
    .unwrap();
    assert_eq!(e.spilled_blocks(), n_blocks);
    assert_eq!(e.disk_used_bytes(), n_blocks as u64 * block_bytes);
    let steps = 3u64;
    for _ in 0..steps {
        e.train_step(&ids).unwrap();
    }
    let (r, w) = e.disk_stats().unwrap();
    // Initial spill writes + one write-back per block per step; one read
    // per block per step.
    assert_eq!(r.bytes, steps * n_blocks as u64 * block_bytes, "NVMe read traffic");
    assert_eq!(w.bytes, (steps + 1) * n_blocks as u64 * block_bytes, "NVMe write traffic");
    let peak = e.dram_window_peak_slots();
    assert!(peak >= 1 && peak <= 2, "staging window peak {peak} must respect 2 slots");
}

#[test]
fn opt175b_fits_64gb_workstation_and_ample_dram_matches_two_tier() {
    let hw = Hardware::a100_pcie4();
    let shape = opt_by_name("OPT-175B").unwrap();
    let wl = Workload { shape, batch: 1, seq: 2048, wire: Codec::Fp16, compute: ComputeMode::Fp16 };
    let costs = SimCost::new(&hw, &wl);
    let sim_steps = 3;

    // Two-tier reference (would need ~700 GB of DRAM for fp32, ~350 for
    // fp16 — far beyond the workstation).
    let two = Policy::default();
    let (s2, _) = simulate(&build_plan(wl.shape.n_layers, sim_steps, two), &costs, two);

    // 18 GB HBM / 64 GB DRAM workstation: every tier peak within budget.
    let budget = MemoryBudget::workstation_64gb();
    assert!(two_tier_dram_bytes(&wl) > budget.dram, "two-tier must not fit this box");
    let plan = plan_three_tier(&wl, &budget, 3, 4, 2, &hw, SpillPlacement::Trailing);
    assert!(plan.spilled_blocks > 0);
    assert!(budget.fits(&plan.peaks), "peaks {:?} vs budget {:?}", plan.peaks, budget);
    let policy = plan.policy();
    assert_eq!(policy.tiering, Tiering::ThreeTier);
    let (s3, _) = simulate(&build_plan(wl.shape.n_layers, sim_steps, policy), &costs, policy);
    assert!(
        s3.steady_step_s >= s2.steady_step_s - 1e-9,
        "the disk tier cannot be faster than DDR"
    );
    // The diagnosis must name the disk as the constraint on this box.
    assert_eq!(s3.bottleneck(), "disk-bound");

    // Ample DRAM (512 GB): nothing spills, schedule degenerates to
    // two-tier, throughput within 25%.
    let ample = MemoryBudget { hbm: budget.hbm, dram: 512 << 30, nvme: budget.nvme };
    let plan = plan_three_tier(&wl, &ample, 3, 4, 2, &hw, SpillPlacement::Trailing);
    assert_eq!(plan.spilled_blocks, 0, "512 GB holds every fp16 bucket");
    let policy = plan.policy();
    let (sa, _) = simulate(&build_plan(wl.shape.n_layers, sim_steps, policy), &costs, policy);
    assert!(
        sa.steady_step_s <= s2.steady_step_s * 1.25,
        "ample-DRAM three-tier {} vs two-tier {} exceeds 25%",
        sa.steady_step_s,
        s2.steady_step_s
    );
}

#[test]
fn throughput_recovers_monotonically_with_dram_budget() {
    // Sweeping the DRAM budget up must never hurt: fewer spills, faster
    // (or equal) steady-state step time.
    let hw = Hardware::a100_pcie4();
    let shape = opt_by_name("OPT-66B").unwrap();
    let wl = Workload { shape, batch: 1, seq: 2048, wire: Codec::Fp16, compute: ComputeMode::Fp16 };
    let costs = SimCost::new(&hw, &wl);
    let mut last = f64::INFINITY;
    let mut spills = Vec::new();
    for gb in [16u64, 32, 64, 128, 256] {
        let budget = MemoryBudget { hbm: 18 << 30, dram: gb << 30, nvme: 2 << 40 };
        let plan = plan_three_tier(&wl, &budget, 3, 4, 2, &hw, SpillPlacement::Trailing);
        let policy = plan.policy();
        let (s, _) = simulate(&build_plan(wl.shape.n_layers, 3, policy), &costs, policy);
        assert!(
            s.steady_step_s <= last + 1e-9,
            "more DRAM ({gb} GB) must not be slower: {} > {last}",
            s.steady_step_s
        );
        last = s.steady_step_s;
        spills.push(plan.spilled_blocks);
    }
    assert!(spills.windows(2).all(|w| w[1] <= w[0]), "spill count falls with DRAM: {spills:?}");
    assert!(spills[0] > spills[4], "the sweep must actually vary placement: {spills:?}");
}
