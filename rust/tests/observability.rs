//! Integration tests for the observability layer: the process-wide metrics
//! sink lifecycle, the Chrome-trace exporter round-trip (file in, file
//! out, schema intact), the drift report over a written trace pair, and
//! the CLI end to end (`simulate --trace-out/--metrics-out` feeding
//! `report`).
//!
//! The global-sink test is deliberately ONE `#[test]` fn: `cargo test`
//! runs tests in one process on many threads, and the enabled flag plus
//! the global registry are process-wide.  Everything else here uses local
//! registries, local timelines, or spawned CLI processes.

use zo2::hostpool::{fused, HostPool};
use zo2::precision::Codec;
use zo2::telemetry::metrics::{self, find_value};
use zo2::telemetry::trace::{
    drift_report, load_trace, write_chrome_trace, DRIFT_SCHEMA, TRACE_SCHEMA,
};
use zo2::telemetry::{Timeline, TraceEvent};
use zo2::util::json::Json;

fn ev(stream: &'static str, cat: &'static str, label: &str, start: f64, end: f64) -> TraceEvent {
    TraceEvent { stream, cat, label: label.to_string(), start, end }
}

fn tmp(name: &str) -> std::path::PathBuf {
    let dir = std::env::temp_dir().join(format!("zo2_obs_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    dir.join(name)
}

/// Disabled → enabled → disabled, in one test because the sink is global.
#[test]
fn global_sink_records_only_while_enabled() {
    assert!(!metrics::enabled(), "sink must be off by default");

    // Disabled: instrumented kernels and the free helpers record nothing.
    let pool = HostPool::new(2);
    let xs = vec![0.25f32; 100];
    let wire = Codec::Bf16.encode(&xs);
    let mut out = vec![0.0f32; xs.len()];
    fused::decode_pooled(Codec::Bf16, &wire, &mut out, &pool);
    metrics::counter_add("t_counter", &[], 3);
    metrics::observe("t_hist", &[], 1.0);
    assert_eq!(metrics::global().len(), 0, "disabled sink must stay empty");

    // Enabled: the same calls land in the registry.
    metrics::set_enabled(true);
    metrics::global().reset();
    fused::decode_pooled(Codec::Bf16, &wire, &mut out, &pool);
    metrics::counter_add("t_counter", &[], 3);
    metrics::counter_add("t_counter", &[], 4);
    let snap = metrics::global().snapshot_json();
    assert_eq!(find_value(&snap, "t_counter", &[]), Some(7.0));
    let entries = snap.get("metrics").unwrap().as_arr().unwrap();
    let chunks = entries
        .iter()
        .find(|e| e.get("name").unwrap().as_str().unwrap() == "hostpool_chunks_per_call")
        .expect("decode_pooled must record a chunk histogram while enabled");
    assert_eq!(chunks.get("kind").unwrap().as_str().unwrap(), "histogram");
    assert_eq!(chunks.get("count").unwrap().as_f64().unwrap(), 1.0);
    let labels = chunks.get("labels").unwrap().as_obj().unwrap();
    assert_eq!(labels.get("codec").unwrap().as_str().unwrap(), "bf16");
    assert_eq!(labels.get("op").unwrap().as_str().unwrap(), "decode");

    // Back off: later records are dropped again.
    metrics::set_enabled(false);
    metrics::global().reset();
    metrics::observe("t_hist", &[], 2.0);
    assert_eq!(metrics::global().len(), 0);
}

#[test]
fn chrome_trace_round_trips_through_a_file() {
    let mut tl = Timeline::new();
    tl.push(ev("compute", "compute", "C b0", 0.0, 2.0));
    tl.push(ev("upload", "upload", "U b0", 0.0, 1.0));
    tl.push(ev("d1.disk_read", "disk_read", "R b1", 0.5, 1.5));
    tl.push(ev("d1.compute", "compute", "C b1", 2.0, 2.0)); // zero duration

    let path = tmp("roundtrip.json");
    write_chrome_trace(path.to_str().unwrap(), &tl).unwrap();
    let doc = load_trace(path.to_str().unwrap()).unwrap();
    let _ = std::fs::remove_file(&path);

    assert_eq!(
        doc.get("otherData").unwrap().get("schema").unwrap().as_str().unwrap(),
        TRACE_SCHEMA
    );
    let events = doc.get("traceEvents").unwrap().as_arr().unwrap();
    let mut last_ts = f64::NEG_INFINITY;
    let mut n_x = 0usize;
    let mut n_meta = 0usize;
    for e in events {
        match e.get("ph").unwrap().as_str().unwrap() {
            "M" => n_meta += 1,
            "X" => {
                n_x += 1;
                let ts = e.get("ts").unwrap().as_f64().unwrap();
                let dur = e.get("dur").unwrap().as_f64().unwrap();
                assert!(dur >= 0.0, "negative duration");
                assert!(ts >= last_ts, "X events must be sorted by ts");
                last_ts = ts;
                let name = e.get("name").unwrap().as_str().unwrap();
                let pid = e.get("pid").unwrap().as_usize().unwrap();
                let tid = e.get("tid").unwrap().as_usize().unwrap();
                match name {
                    // pid = device index, tid = fixed stream-kind index.
                    "C b0" => assert_eq!((pid, tid), (0, 1)),
                    "U b0" => assert_eq!((pid, tid), (0, 0)),
                    "R b1" => assert_eq!((pid, tid), (1, 3)),
                    "C b1" => {
                        assert_eq!((pid, tid), (1, 1));
                        assert_eq!(dur, 0.0);
                    }
                    n => panic!("unexpected event {n}"),
                }
            }
            ph => panic!("unexpected phase {ph}"),
        }
    }
    assert_eq!(n_x, 4);
    // 2 process_name (devices 0 and 1) + 4 thread_name records.
    assert_eq!(n_meta, 6);
    let thread_names: Vec<&str> = events
        .iter()
        .filter(|e| {
            e.get("ph").unwrap().as_str().unwrap() == "M"
                && e.get("name").unwrap().as_str().unwrap() == "thread_name"
        })
        .map(|e| e.get("args").unwrap().get("name").unwrap().as_str().unwrap())
        .collect();
    assert_eq!(thread_names, ["upload", "compute", "compute", "disk_read"]);
}

#[test]
fn drift_report_over_a_written_pair() {
    let mut sim = Timeline::new();
    sim.push(ev("upload", "upload", "U b0", 0.0, 1.0));
    sim.push(ev("compute", "compute", "C b0", 1.0, 3.0));
    let mut measured = Timeline::new();
    measured.push(ev("upload", "upload", "U b0", 0.0, 1.5));
    measured.push(ev("compute", "compute", "C b0", 1.5, 5.5));

    let ps = tmp("pair_sim.json");
    let pm = tmp("pair_measured.json");
    write_chrome_trace(ps.to_str().unwrap(), &sim).unwrap();
    write_chrome_trace(pm.to_str().unwrap(), &measured).unwrap();
    let rep = drift_report(
        &load_trace(ps.to_str().unwrap()).unwrap(),
        &load_trace(pm.to_str().unwrap()).unwrap(),
    )
    .unwrap();
    let _ = std::fs::remove_file(&ps);
    let _ = std::fs::remove_file(&pm);

    assert_eq!(rep.get("schema").unwrap().as_str().unwrap(), DRIFT_SCHEMA);
    let mk = rep.get("makespan_s").unwrap();
    assert!((mk.get("sim").unwrap().as_f64().unwrap() - 3.0).abs() < 1e-9);
    assert!((mk.get("measured").unwrap().as_f64().unwrap() - 5.5).abs() < 1e-9);
    let streams = rep.get("streams").unwrap().as_arr().unwrap();
    assert_eq!(streams.len(), 2);
    let compute = streams
        .iter()
        .find(|s| s.get("stream").unwrap().as_str().unwrap() == "compute")
        .unwrap();
    assert!((compute.get("ratio").unwrap().as_f64().unwrap() - 2.0).abs() < 1e-9);
    let kinds = rep.get("task_kinds").unwrap().as_arr().unwrap();
    assert_eq!(kinds.len(), 2);
}

/// `simulate --trace-out/--metrics-out` twice (overlap vs sequential
/// schedule of the same model), then `report` over the pair — the whole
/// CLI surface this PR adds, in fresh processes.
#[test]
fn cli_simulate_then_report() {
    let bin = env!("CARGO_BIN_EXE_zo2");
    let t_sim = tmp("cli_sim_trace.json");
    let m_sim = tmp("cli_sim_metrics.json");
    let t_seq = tmp("cli_seq_trace.json");
    let drift = tmp("cli_drift.json");
    let run = |args: &[&str]| -> String {
        let out = std::process::Command::new(bin)
            .args(args)
            .current_dir(std::env::temp_dir())
            .output()
            .expect("spawn zo2");
        assert!(
            out.status.success(),
            "zo2 {:?} failed:\n{}{}",
            args,
            String::from_utf8_lossy(&out.stdout),
            String::from_utf8_lossy(&out.stderr)
        );
        String::from_utf8_lossy(&out.stdout).to_string()
    };

    run(&[
        "simulate",
        "--model",
        "OPT-13B",
        "--sim-steps",
        "2",
        "--trace-out",
        t_sim.to_str().unwrap(),
        "--metrics-out",
        m_sim.to_str().unwrap(),
    ]);
    run(&[
        "simulate",
        "--model",
        "OPT-13B",
        "--sim-steps",
        "2",
        "--mode",
        "seq",
        "--trace-out",
        t_seq.to_str().unwrap(),
    ]);

    // Metrics snapshot: schema + a positive makespan and per-stream busy.
    let snap = Json::parse(&std::fs::read_to_string(&m_sim).unwrap()).unwrap();
    assert_eq!(snap.get("schema").unwrap().as_str().unwrap(), "zo2-metrics-v1");
    let makespan = find_value(&snap, "sim_makespan_s", &[]).unwrap();
    assert!(makespan > 0.0);
    let compute_busy =
        find_value(&snap, "sim_stream_busy_s", &[("device", "0"), ("stream", "compute")])
            .unwrap();
    assert!(compute_busy > 0.0 && compute_busy <= makespan + 1e-9);

    // Trace files parse and carry events.
    for p in [&t_sim, &t_seq] {
        let doc = load_trace(p.to_str().unwrap()).unwrap();
        assert!(!doc.get("traceEvents").unwrap().as_arr().unwrap().is_empty());
    }

    let stdout = run(&[
        "report",
        "--sim",
        t_sim.to_str().unwrap(),
        "--measured",
        t_seq.to_str().unwrap(),
        "--out",
        drift.to_str().unwrap(),
    ]);
    assert!(stdout.contains("makespan:"), "report must print the makespan line:\n{stdout}");

    let rep = Json::parse(&std::fs::read_to_string(&drift).unwrap()).unwrap();
    assert_eq!(rep.get("schema").unwrap().as_str().unwrap(), "zo2-drift-v1");
    assert!(!rep.get("streams").unwrap().as_arr().unwrap().is_empty());
    assert!(!rep.get("task_kinds").unwrap().as_arr().unwrap().is_empty());
    // The sequential schedule of the same plan can only be slower.
    let mk = rep.get("makespan_s").unwrap();
    assert!(
        mk.get("measured").unwrap().as_f64().unwrap()
            >= mk.get("sim").unwrap().as_f64().unwrap() - 1e-9
    );

    for p in [&t_sim, &m_sim, &t_seq, &drift] {
        let _ = std::fs::remove_file(p);
    }
}
