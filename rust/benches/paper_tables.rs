//! Regenerates every table and figure of the paper's evaluation (§7).
//!
//!     cargo bench --bench paper_tables            # all
//!     cargo bench --bench paper_tables -- table2  # one
//!
//! Paper-scale OPT models run through the discrete-event simulator (the
//! real scheduler/dependency logic on virtual time with the calibrated
//! A100-PCIe4 cost model — see DESIGN.md §Hardware-Adaptation); the tiny
//! config additionally runs for real to anchor Table 3 and Figure 4.
//! Absolute numbers are not expected to match the authors' testbed; the
//! *shapes* (who wins, by what factor, where crossovers fall) are.

use std::collections::BTreeMap;

use zo2::baselines::{comm_ops_per_block, first_order_comm_per_step, zo2_comm_per_step};
use zo2::costmodel::{
    gpu_memory_bytes, mezo_step_s, plan_three_tier, plan_three_tier_owned,
    plan_three_tier_partitioned, two_tier_dram_bytes, Cluster, ClusterCost, ComputeMode, Hardware,
    Interconnect, MemoryBudget, SimCost, Strategy, Workload,
};
use zo2::hostpool::{fused, HostPool};
use zo2::model::{opt_by_name, opt_family, ModelShape};
use zo2::precision::Codec;
use zo2::rng::{GaussianRng, RngState};
use zo2::sched::{build_plan, simulate, Policy, SpillPlacement, Tiering};
use zo2::shard::{
    blocks_per_device_of, bottleneck_weights, build_sharded_plan, build_sharded_plan_tiered,
    weighted_contiguous_owners, DeviceTier, ShardLayout, ShardSpec,
};
use zo2::simd::{self, SimdMode};
use zo2::telemetry::metrics::MetricsRegistry;
use zo2::tune::{evaluate, tune, Scenario, SearchSpace, TuneOpts, Verdict};
use zo2::util::fmt_mb;
use zo2::util::json::Json;
use zo2::util::stats::bench;
use zo2::zo::{cpu_zo_sgd_update, ZScratch};

const SIM_STEPS: usize = 4;

fn wl(shape: &ModelShape, batch: usize, seq: usize, wire: Codec, compute: ComputeMode) -> Workload {
    Workload { shape: shape.clone(), batch, seq, wire, compute }
}

/// ZO2 steady-state tokens/s under `policy`.
fn zo2_tokens_per_s(hw: &Hardware, w: &Workload, policy: Policy) -> f64 {
    let costs = SimCost::new(hw, w);
    let plan = build_plan(w.shape.n_layers, SIM_STEPS, policy);
    let (sched, _) = simulate(&plan, &costs, policy);
    (w.batch * w.seq) as f64 / sched.steady_step_s
}

/// MeZO tokens/s (resident; `None` when it does not fit in HBM).
fn mezo_tokens_per_s(hw: &Hardware, w: &Workload, param_bytes: usize) -> Option<f64> {
    let mem = gpu_memory_bytes(Strategy::Mezo, w, param_bytes, hw);
    if mem > hw.hbm_capacity {
        return None;
    }
    let costs = SimCost::new(hw, w);
    Some((w.batch * w.seq) as f64 / mezo_step_s(hw, w))
}

fn fig1_memory(hw: &Hardware) {
    println!("\n=== Figure 1: GPU memory by optimizer (B=1, T=2048; MB; X = >80GB) ===");
    println!("{:<10} {:>10} {:>10} {:>10} {:>10}", "model", "AdamW", "SGD", "MeZO", "ZO2");
    for shape in opt_family() {
        let w = wl(&shape, 1, 2048, Codec::F32, ComputeMode::Fp32);
        let cell = |s: Strategy| {
            let b = gpu_memory_bytes(s, &w, 4, hw);
            if b > hw.hbm_capacity {
                "X".to_string()
            } else {
                fmt_mb(b)
            }
        };
        println!(
            "{:<10} {:>10} {:>10} {:>10} {:>10}",
            shape.name,
            cell(Strategy::AdamW),
            cell(Strategy::Sgd),
            cell(Strategy::Mezo),
            cell(Strategy::Zo2 { slots: 3 })
        );
    }
}

fn table2_main(hw: &Hardware) {
    println!("\n=== Table 2: memory (MB) + throughput (tokens/s), MeZO vs ZO2, FP32/FP16 ===");
    println!(
        "{:<10} | {:>9} {:>12} {:>9} {:>12} | {:>9} {:>11} {:>9} {:>11}",
        "model", "MeZO32", "ZO2-32", "MeZO16", "ZO2-16", "MeZO32", "ZO2-32", "MeZO16", "ZO2-16"
    );
    for shape in opt_family() {
        let mut mem = Vec::new();
        let mut thr = Vec::new();
        for (pbytes, wire, cm) in
            [(4usize, Codec::F32, ComputeMode::Fp32), (2, Codec::Fp16, ComputeMode::Fp16)]
        {
            let w = wl(&shape, 1, 2048, wire, cm);
            let mz_mem = gpu_memory_bytes(Strategy::Mezo, &w, pbytes, hw);
            let zo_mem = gpu_memory_bytes(Strategy::Zo2 { slots: 3 }, &w, pbytes, hw);
            let mz_thr = mezo_tokens_per_s(hw, &w, pbytes);
            let zo_thr = zo2_tokens_per_s(hw, &w, Policy::default());
            let ratio_mem = zo_mem as f64 / mz_mem as f64;
            mem.push(match mz_thr {
                Some(_) => format!("{}", fmt_mb(mz_mem)),
                None => "-".into(),
            });
            mem.push(format!("{}(x{ratio_mem:.2})", fmt_mb(zo_mem)));
            thr.push(match mz_thr {
                Some(t) => format!("{t:.0}"),
                None => "-".into(),
            });
            thr.push(match mz_thr {
                Some(t) => format!("{:.0}(x{:.2})", zo_thr, zo_thr / t),
                None => format!("{zo_thr:.0}"),
            });
        }
        println!(
            "{:<10} | {:>9} {:>12} {:>9} {:>12} | {:>9} {:>11} {:>9} {:>11}",
            shape.name, mem[0], mem[1], mem[2], mem[3], thr[0], thr[1], thr[2], thr[3]
        );
    }
    println!("(paper: ZO2 ~x0.97-0.98 of MeZO throughput; memory ratio shrinking with size;");
    println!(" 30B+ MeZO = '-' (OOM); ZO2 OPT-175B fp16 ~18GB)");
}

fn table4_ablation(hw: &Hardware) {
    println!("\n=== Table 4: reverse ablation, throughput (tokens/s) ===");
    println!(
        "{:<10} {:>9} {:>14} {:>14} {:>14} {:>9}",
        "model", "MeZO", "no-scheduler", "no-reuse-mem", "no-eff-update", "ZO2"
    );
    for shape in opt_family() {
        let w = wl(&shape, 1, 2048, Codec::F32, ComputeMode::Fp32);
        let mz = mezo_tokens_per_s(hw, &w, 4);
        let full = zo2_tokens_per_s(hw, &w, Policy::default());
        let nosched = zo2_tokens_per_s(hw, &w, Policy::naive());
        let noreuse = zo2_tokens_per_s(hw, &w, Policy { reusable_mem: false, ..Policy::default() });
        let noeff =
            zo2_tokens_per_s(hw, &w, Policy { efficient_update: false, ..Policy::default() });
        let r = |t: f64| match mz {
            Some(m) => format!("{t:.0}(x{:.2})", t / m),
            None => format!("{t:.0}"),
        };
        println!(
            "{:<10} {:>9} {:>14} {:>14} {:>14} {:>9}",
            shape.name,
            mz.map(|t| format!("{t:.0}")).unwrap_or("-".into()),
            r(nosched),
            r(noreuse),
            r(noeff),
            r(full)
        );
    }
    println!("(paper: no-reuse worst x0.37-0.39, no-scheduler x0.39-0.56, no-eff x0.74-0.78)");
}

fn table5_amp(hw: &Hardware) {
    println!("\n=== Table 5: AMP mode, throughput (tokens/s) by compression codec ===");
    for cm in [ComputeMode::Fp16, ComputeMode::Bf16] {
        println!(
            "-- autocast {} --\n{:<10} {:>12} {:>14} {:>14} {:>14}",
            cm.name(), "model", "non-compress", "fp16", "bf16", "fp8"
        );
        for shape in opt_family() {
            let base = zo2_tokens_per_s(hw, &wl(&shape, 1, 2048, Codec::F32, cm), Policy::default());
            let row: Vec<String> = [Codec::Fp16, Codec::Bf16, Codec::Fp8E4M3]
                .iter()
                .map(|&c| {
                    let t = zo2_tokens_per_s(hw, &wl(&shape, 1, 2048, c, cm), Policy::default());
                    format!("{t:.0}(x{:.3})", t / base)
                })
                .collect();
            println!(
                "{:<10} {:>12.0} {:>14} {:>14} {:>14}",
                shape.name, base, row[0], row[1], row[2]
            );
        }
    }
    println!("(paper: compression wins x1.3-1.7 for >=6.7B; ~x0.99 at 1.3B; fp8 best)");
}

fn table6_batch(hw: &Hardware) {
    println!("\n=== Table 6: batch-size sweep (memory MB / tokens/s) ===");
    println!(
        "{:<10} {:>3} | {:>10} {:>14} | {:>9} {:>13}",
        "model", "B", "MeZO-mem", "ZO2-mem", "MeZO-t/s", "ZO2-t/s"
    );
    for b in [1usize, 2, 4, 8] {
        for name in ["OPT-1.3B", "OPT-2.7B", "OPT-6.7B", "OPT-13B"] {
            let shape = opt_by_name(name).unwrap();
            let w = wl(&shape, b, 2048, Codec::F32, ComputeMode::Fp32);
            let mz_mem = gpu_memory_bytes(Strategy::Mezo, &w, 4, hw);
            let zo_mem = gpu_memory_bytes(Strategy::Zo2 { slots: 3 }, &w, 4, hw);
            let mz = mezo_tokens_per_s(hw, &w, 4);
            let zo = zo2_tokens_per_s(hw, &w, Policy::default());
            println!(
                "{:<10} {:>3} | {:>10} {:>8}(x{:.2}) | {:>9} {:>7}({})",
                name,
                b,
                if mz.is_some() { fmt_mb(mz_mem) } else { "-".into() },
                fmt_mb(zo_mem),
                zo_mem as f64 / mz_mem as f64,
                mz.map(|t| format!("{t:.0}")).unwrap_or("-".into()),
                format!("{zo:.0}"),
                mz.map(|t| format!("x{:.2}", zo / t)).unwrap_or("-".into()),
            );
        }
    }
    println!("(paper: throughput parity x0.97-0.99 at every batch size)");
}

fn table7_seqlen(hw: &Hardware) {
    println!("\n=== Table 7: sequence-length sweep (memory MB / tokens/s) ===");
    println!(
        "{:<10} {:>5} | {:>10} {:>14} | {:>9} {:>13}",
        "model", "T", "MeZO-mem", "ZO2-mem", "MeZO-t/s", "ZO2-t/s"
    );
    for t in [1024usize, 2048, 4096, 8192] {
        for name in ["OPT-1.3B", "OPT-2.7B", "OPT-6.7B", "OPT-13B"] {
            let shape = opt_by_name(name).unwrap();
            let w = wl(&shape, 1, t, Codec::F32, ComputeMode::Fp32);
            let mz_mem = gpu_memory_bytes(Strategy::Mezo, &w, 4, hw);
            let zo_mem = gpu_memory_bytes(Strategy::Zo2 { slots: 3 }, &w, 4, hw);
            let mz = mezo_tokens_per_s(hw, &w, 4);
            let zo = zo2_tokens_per_s(hw, &w, Policy::default());
            println!(
                "{:<10} {:>5} | {:>10} {:>8}(x{:.2}) | {:>9} {:>7}({})",
                name,
                t,
                if mz.is_some() { fmt_mb(mz_mem) } else { "-".into() },
                fmt_mb(zo_mem),
                zo_mem as f64 / mz_mem as f64,
                mz.map(|x| format!("{x:.0}")).unwrap_or("-".into()),
                format!("{zo:.0}"),
                mz.map(|x| format!("x{:.2}", zo / x)).unwrap_or("-".into()),
            );
        }
    }
}

fn fig3_comm(_hw: &Hardware) {
    println!("\n=== Figure 3: per-step interconnect traffic, first-order vs ZO2 ===");
    println!("{:<10} {:>12} {:>12} {:>7} | ops/block: FO {} vs ZO {}",
             "model", "FO (MB)", "ZO2 (MB)", "ratio",
             comm_ops_per_block(true), comm_ops_per_block(false));
    for shape in opt_family().into_iter().take(4) {
        let w = wl(&shape, 1, 2048, Codec::F32, ComputeMode::Fp32);
        let fo = first_order_comm_per_step(&w);
        let zo = zo2_comm_per_step(&w);
        println!(
            "{:<10} {:>12} {:>12} {:>6.1}x",
            shape.name,
            fmt_mb(fo.total()),
            fmt_mb(zo.total()),
            fo.total() as f64 / zo.total() as f64
        );
    }
}

fn fig4_timeline(hw: &Hardware) {
    println!("\n=== Figure 4: naive vs overlapped schedule (OPT-13B fp32, 1 step) ===");
    let shape = opt_by_name("OPT-13B").unwrap();
    let w = wl(&shape, 1, 2048, Codec::F32, ComputeMode::Fp32);
    let costs = SimCost::new(hw, &w);
    for (label, policy) in [("naive (Fig. 4a)", Policy::naive()), ("overlapped (Fig. 4b)", Policy::default())] {
        let plan = build_plan(shape.n_layers, 1, policy);
        let (sched, tl) = simulate(&plan, &costs, policy);
        println!("-- {label}: makespan {:.3}s --", sched.makespan);
        println!("{}", tl.to_ascii_gantt(100));
    }
}

/// Extra design-choice ablations beyond the paper's Table 4 (DESIGN.md §7).
fn ablations(hw: &Hardware) {
    println!("\n=== Ablations beyond the paper (DESIGN.md §7) ===");
    let shape = opt_by_name("OPT-13B").unwrap();
    let w = wl(&shape, 1, 2048, Codec::F32, ComputeMode::Fp32);

    // (a) prefetch depth: slot-ring size 1..4.
    println!("-- reusable-buffer slots (prefetch depth), OPT-13B fp32 --");
    for slots in [1usize, 2, 3, 4] {
        let t = zo2_tokens_per_s(hw, &w, Policy { slots, ..Policy::default() });
        println!("  slots={slots}: {t:.0} tokens/s");
    }

    // (b) bucketed vs per-tensor transfers (§5.3 communication buckets):
    // without bucketing, each of the block's 16 tensors is a separate
    // cudaMemcpyAsync — paying per-op driver overhead (~300 µs) instead of
    // one launch per block.  Visible in the comm-bound AMP regime.
    let w_amp = wl(&shape, 1, 2048, Codec::F32, ComputeMode::Fp16);
    struct PerTensor<'a>(SimCost<'a>, usize, f64);
    impl<'a> zo2::sched::CostProvider for PerTensor<'a> {
        fn upload_s(&self) -> f64 {
            self.0.upload_s() + self.1 as f64 * self.2
        }
        fn offload_s(&self) -> f64 {
            self.0.offload_s() + self.1 as f64 * self.2
        }
        fn compute_s(&self, m: zo2::sched::Module) -> f64 {
            self.0.compute_s(m)
        }
        fn update_s(&self) -> f64 {
            self.0.update_s()
        }
    }
    let policy = Policy::default();
    let plan = build_plan(shape.n_layers, SIM_STEPS, policy);
    let bucketed = SimCost::new(hw, &w_amp);
    let (sb, _) = simulate(&plan, &bucketed, policy);
    let per_tensor = PerTensor(SimCost::new(hw, &w_amp), 16, 300e-6);
    let (spt, _) = simulate(&plan, &per_tensor, policy);
    println!(
        "-- transfers (AMP comm-bound regime): bucketed {:.0} tokens/s vs \
         per-tensor(16 frags) {:.0} tokens/s (x{:.3})",
        2048.0 / sb.steady_step_s,
        2048.0 / spt.steady_step_s,
        (2048.0 / spt.steady_step_s) / (2048.0 / sb.steady_step_s)
    );

    // (c) the paper's §8 limitation, quantified: eval/inference runs a
    // SINGLE forward per block, halving compute while uploads stay — the
    // overlap that hides transfers during training breaks down.
    let w16 = wl(&shape, 1, 2048, Codec::Fp16, ComputeMode::Fp16);
    struct SingleFwd<'a>(SimCost<'a>);
    impl<'a> zo2::sched::CostProvider for SingleFwd<'a> {
        fn upload_s(&self) -> f64 {
            self.0.upload_s()
        }
        fn offload_s(&self) -> f64 {
            // Eval doesn't write parameters back; offload is a slot release.
            1e-6
        }
        fn compute_s(&self, m: zo2::sched::Module) -> f64 {
            self.0.compute_s(m) / 2.0 // single forward, no update
        }
        fn update_s(&self) -> f64 {
            0.0
        }
    }
    let train16 = SimCost::new(hw, &w16);
    let (st16, _) = simulate(&plan, &train16, policy);
    let single = SingleFwd(SimCost::new(hw, &w16));
    let (se, _) = simulate(&plan, &single, policy);
    let train_tps = 2048.0 / st16.steady_step_s;
    let eval_tps = 2048.0 / se.steady_step_s;
    println!(
        "-- §8 limitation (fp16): train {:.0} tokens/s, streamed eval {:.0} tokens/s \
         = only {:.2}x of the 2x single-forward headroom (comm-bound)",
        train_tps, eval_tps, eval_tps / (2.0 * train_tps)
    );

    // (d) ZO-AdamW (host-side moments): device memory unchanged; host gains
    // 2 x params fp32 — the ZeRO-Offload trade reproduced for ZO.
    let host_extra = 2u64 * shape.total_params() as u64 * 4;
    println!(
        "-- ZO-AdamW: device bytes unchanged; host optimizer state +{} MB (2x params fp32)",
        fmt_mb(host_extra)
    );
}

/// Beyond the paper: the disk tier's throughput/DRAM trade.  Sweeps the
/// DRAM budget at fixed model size (OPT-175B fp16, 18 GB HBM) and writes
/// `BENCH_disk_tier.json` so the perf trajectory of the three-tier
/// subsystem is tracked across PRs.
fn table_disk_tier(hw: &Hardware) {
    println!("\n=== Disk tier: OPT-175B fp16 throughput vs DRAM budget (18 GB HBM) ===");
    let shape = opt_by_name("OPT-175B").unwrap();
    let w = wl(&shape, 1, 2048, Codec::Fp16, ComputeMode::Fp16);
    let costs = SimCost::new(hw, &w);
    let tokens = 2048.0;

    let two = Policy::default();
    let (s2, _) = simulate(&build_plan(shape.n_layers, SIM_STEPS, two), &costs, two);
    let base_tps = tokens / s2.steady_step_s;

    println!(
        "{:>9} {:>9} {:>10} {:>9} {:>14}  (two-tier: {base_tps:.1} tokens/s, DDR {} MB)",
        "DRAM", "spilled", "tokens/s", "vs 2tier", "bottleneck",
        fmt_mb(two_tier_dram_bytes(&w))
    );
    let mut rows: Vec<Json> = Vec::new();
    for gb in [16u64, 32, 64, 128, 256, 512] {
        let budget = MemoryBudget { hbm: 18 << 30, dram: gb << 30, nvme: 2 << 40 };
        let plan = plan_three_tier(&w, &budget, 3, 4, 2, hw, SpillPlacement::Trailing);
        let policy = plan.policy();
        let (s, _) = simulate(&build_plan(shape.n_layers, SIM_STEPS, policy), &costs, policy);
        let tps = tokens / s.steady_step_s;
        println!(
            "{:>6} GB {:>9} {:>10.1} {:>8.2}x {:>14}",
            gb,
            plan.spilled_blocks,
            tps,
            tps / base_tps,
            s.bottleneck()
        );
        let mut row = BTreeMap::new();
        row.insert("dram_gb".to_string(), Json::Num(gb as f64));
        row.insert("spilled_blocks".to_string(), Json::Num(plan.spilled_blocks as f64));
        row.insert("resident_blocks".to_string(), Json::Num(plan.resident_blocks as f64));
        row.insert("tokens_per_s".to_string(), Json::Num(tps));
        row.insert("ratio_vs_two_tier".to_string(), Json::Num(tps / base_tps));
        row.insert("bottleneck".to_string(), Json::Str(s.bottleneck().to_string()));
        row.insert("hbm_peak_bytes".to_string(), Json::Num(plan.peaks.hbm as f64));
        row.insert("dram_peak_bytes".to_string(), Json::Num(plan.peaks.dram as f64));
        row.insert("nvme_peak_bytes".to_string(), Json::Num(plan.peaks.nvme as f64));
        rows.push(Json::Obj(row));
    }
    let mut doc = BTreeMap::new();
    doc.insert("bench".to_string(), Json::Str("disk_tier".to_string()));
    doc.insert("model".to_string(), Json::Str("OPT-175B".to_string()));
    doc.insert("wire".to_string(), Json::Str("fp16".to_string()));
    doc.insert("hbm_gb".to_string(), Json::Num(18.0));
    doc.insert("two_tier_tokens_per_s".to_string(), Json::Num(base_tps));
    doc.insert("rows".to_string(), Json::Arr(rows));
    let path = "BENCH_disk_tier.json";
    match std::fs::write(path, Json::Obj(doc).to_string_pretty()) {
        Ok(()) => println!("wrote {path}"),
        Err(e) => println!("could not write {path}: {e}"),
    }

    // Spill placement ablation at the 64 GB point: interleaving the spilled
    // blocks through the step vs the trailing burst.
    let budget = MemoryBudget { hbm: 18 << 30, dram: 64 << 30, nvme: 2 << 40 };
    for placement in [SpillPlacement::Trailing, SpillPlacement::Interleaved] {
        let plan = plan_three_tier(&w, &budget, 3, 4, 2, hw, placement);
        let policy = plan.policy();
        let (s, _) = simulate(&build_plan(shape.n_layers, SIM_STEPS, policy), &costs, policy);
        println!(
            "  64 GB, {placement:?}: {:.1} tokens/s ({})",
            tokens / s.steady_step_s,
            s.bottleneck()
        );
    }
}

/// Tentpole bench: host-kernel throughput per codec — decode-only and
/// encode-only passes, the scalar three-pass (decode → update → encode)
/// composition, the fused single pass, and fused+pool at 1/2/4/8 threads —
/// each timed under both `--host-simd off` (scalar) and `auto` (vector)
/// dispatch, plus a pinned (`--host-pin`) 8-thread fused variant.  Writes
/// `BENCH_host_kernels.json`, including the per-thread SIMD GB/s constants
/// that calibrate `costmodel::HostKernels` (legacy `calibration` block and
/// the telemetry-snapshot gauge `from_bench_json` prefers).
/// `ZO2_HOST_KERNEL_ELEMS` overrides the bucket size (CI smoke uses a tiny
/// one).  Every variant is asserted bit-identical before timing.
fn table_host_kernels(_hw: &Hardware) {
    let elems: usize = std::env::var("ZO2_HOST_KERNEL_ELEMS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(1 << 22);
    println!(
        "\n=== Host kernels: decode/update/encode throughput ({elems} elems, \
         avx2 {}) ===",
        if simd::avx2_supported() { "available" } else { "unavailable: simd == scalar" }
    );
    println!(
        "{:>5} | {:>11} {:>11} {:>11} | {:>11} {:>11} | {:>9} {:>9} | {:>6} {:>6}",
        "codec",
        "dec s/v",
        "enc s/v",
        "3pass",
        "fused s/v",
        "p8 s/v",
        "p8 pin",
        "p1..p4 v",
        "xfuse",
        "xsimd"
    );

    let mut xs = vec![0.0f32; elems];
    GaussianRng::new(2025, 1).fill_gaussian(&mut xs);
    for x in xs.iter_mut() {
        *x *= 0.02; // parameter-scale values (fp8-representable)
    }
    let state = RngState { seed: 9, stream: 4, counter: 0 };
    let (lr, g) = (1e-4f32, 0.8f32);
    let gbs = |t: f64| (elems * 4) as f64 / t / 1e9;
    let thread_counts = [1usize, 2, 4, 8];

    /// p50 timings of every variant under one dispatch mode.
    struct ModeTimes {
        decode: f64,
        encode: f64,
        three_pass: f64,
        fused_serial: f64,
        /// One entry per `thread_counts` element.
        pooled: Vec<f64>,
        pinned8: f64,
    }
    let run = |codec: Codec, wire0: &[u8], mode: SimdMode| -> ModeTimes {
        simd::set_mode(mode);
        let mut tmp = vec![0.0f32; elems];
        let decode = bench(1, 5, || codec.decode_into(wire0, &mut tmp)).percentile(50.0);
        let mut enc = Vec::new();
        let encode = bench(1, 5, || codec.encode_into(&tmp, &mut enc)).percentile(50.0);
        // Three passes + a bucket-sized fp32 intermediate (the pre-fusion
        // composition; under `off` this is the historical scalar baseline).
        let mut bytes = wire0.to_vec();
        let mut zs = ZScratch::new();
        let three_pass = bench(1, 5, || {
            codec.decode_into(&bytes, &mut tmp);
            cpu_zo_sgd_update(&mut tmp, state, lr, g, &mut zs);
            codec.encode_into(&tmp, &mut bytes);
        })
        .percentile(50.0);
        // Fused single pass, serial (fusion win without the pool).
        let serial_pool = HostPool::new(1);
        let mut bytes = wire0.to_vec();
        let fused_serial = bench(1, 5, || {
            fused::fused_zo_sgd(codec, &mut bytes, elems, state, lr, g, &serial_pool);
        })
        .percentile(50.0);
        // Fused + pool across thread counts.
        let mut pooled = Vec::new();
        for &threads in &thread_counts {
            let pool = HostPool::new(threads);
            let mut bytes = wire0.to_vec();
            let t = bench(1, 5, || {
                fused::fused_zo_sgd(codec, &mut bytes, elems, state, lr, g, &pool);
            })
            .percentile(50.0);
            pooled.push(t);
        }
        // Fused + pinned 8-thread pool (`--host-pin`: static chunk→worker
        // map, workers pinned across NUMA nodes).
        let pin_pool = HostPool::with_opts(8, true);
        let mut bytes = wire0.to_vec();
        let pinned8 = bench(1, 5, || {
            fused::fused_zo_sgd(codec, &mut bytes, elems, state, lr, g, &pin_pool);
        })
        .percentile(50.0);
        ModeTimes { decode, encode, three_pass, fused_serial, pooled, pinned8 }
    };

    let mut rows: Vec<Json> = Vec::new();
    let mut calib = BTreeMap::new();
    // Local (non-global) registry: the calibration constants are also
    // emitted as a telemetry snapshot so `HostKernels::from_bench_json`
    // and external tooling read one schema (`zo2-metrics-v1`).
    let reg = MetricsRegistry::new();
    for codec in [Codec::F32, Codec::Bf16, Codec::Fp16, Codec::Fp8E4M3] {
        let wire0 = codec.encode(&xs);

        // Bit-identity: the scalar composition is the specification; the
        // fused+pool (and pinned) paths must reproduce it bit-for-bit under
        // BOTH dispatch modes before anything is timed.
        {
            simd::set_mode(SimdMode::Off);
            let mut want_f32 = codec.decode(&wire0, elems);
            let mut zs = ZScratch::new();
            cpu_zo_sgd_update(&mut want_f32, state, lr, g, &mut zs);
            let want = codec.encode(&want_f32);
            for mode in [SimdMode::Off, SimdMode::Auto] {
                simd::set_mode(mode);
                for pin in [false, true] {
                    let pool = HostPool::with_opts(8, pin);
                    let mut got = wire0.clone();
                    fused::fused_zo_sgd(codec, &mut got, elems, state, lr, g, &pool);
                    assert_eq!(
                        got, want,
                        "{codec:?} {mode:?} pin={pin}: fused+pool must be bit-identical"
                    );
                }
            }
        }

        let off = run(codec, &wire0, SimdMode::Off);
        let auto = run(codec, &wire0, SimdMode::Auto);
        let best = auto.pooled.last().copied().unwrap_or(auto.fused_serial);
        let best_off = off.pooled.last().copied().unwrap_or(off.fused_serial);
        println!(
            "{:>5} | {:>5.1}/{:<5.1} {:>5.1}/{:<5.1} {:>11.2} | {:>5.1}/{:<5.1} {:>5.1}/{:<5.1} \
             | {:>9.1} {:>4.1} {:>4.1} | {:>5.2}x {:>5.2}x",
            codec.name(),
            gbs(off.decode),
            gbs(auto.decode),
            gbs(off.encode),
            gbs(auto.encode),
            gbs(off.three_pass),
            gbs(off.fused_serial),
            gbs(auto.fused_serial),
            gbs(best_off),
            gbs(best),
            gbs(auto.pinned8),
            gbs(auto.pooled[0]),
            gbs(auto.pooled[2]),
            off.three_pass / best,
            best_off / best
        );

        let mut row = BTreeMap::new();
        row.insert("codec".to_string(), Json::Str(codec.name().to_string()));
        row.insert("elems".to_string(), Json::Num(elems as f64));
        row.insert("decode_scalar_gbps".to_string(), Json::Num(gbs(off.decode)));
        row.insert("decode_simd_gbps".to_string(), Json::Num(gbs(auto.decode)));
        row.insert("encode_scalar_gbps".to_string(), Json::Num(gbs(off.encode)));
        row.insert("encode_simd_gbps".to_string(), Json::Num(gbs(auto.encode)));
        row.insert("scalar_gbps".to_string(), Json::Num(gbs(off.three_pass)));
        row.insert("fused_serial_scalar_gbps".to_string(), Json::Num(gbs(off.fused_serial)));
        row.insert("fused_serial_gbps".to_string(), Json::Num(gbs(auto.fused_serial)));
        for (i, &threads) in thread_counts.iter().enumerate() {
            row.insert(format!("fused_pool{threads}_gbps"), Json::Num(gbs(auto.pooled[i])));
            row.insert(
                format!("fused_pool{threads}_scalar_gbps"),
                Json::Num(gbs(off.pooled[i])),
            );
        }
        row.insert("fused_pool8_pinned_gbps".to_string(), Json::Num(gbs(auto.pinned8)));
        row.insert(
            "speedup_fused_pool8_vs_scalar".to_string(),
            Json::Num(off.three_pass / best),
        );
        row.insert(
            "speedup_simd_vs_scalar_fused_pool8".to_string(),
            Json::Num(best_off / best),
        );
        rows.push(Json::Obj(row));
        // Calibration constant: per-thread rate of the serial fused pass
        // with SIMD dispatch on (what `costmodel::HostKernels` consumes,
        // × threads; on non-AVX2 hosts this equals the scalar rate).
        calib.insert(
            format!("{}_bytes_per_s_per_thread", codec.name()),
            Json::Num(gbs(auto.fused_serial) * 1e9),
        );
        reg.gauge_set(
            "host_kernel_bytes_per_s_per_thread",
            &[("codec", codec.name())],
            gbs(auto.fused_serial) * 1e9,
        );
    }
    simd::set_mode(SimdMode::Auto); // restore the process default

    let mut doc = BTreeMap::new();
    doc.insert("bench".to_string(), Json::Str("host_kernels".to_string()));
    doc.insert("elems".to_string(), Json::Num(elems as f64));
    doc.insert("avx2".to_string(), Json::Bool(simd::avx2_supported()));
    doc.insert("rows".to_string(), Json::Arr(rows));
    doc.insert("calibration".to_string(), Json::Obj(calib));
    doc.insert("metrics".to_string(), reg.snapshot_json());
    let path = "BENCH_host_kernels.json";
    match std::fs::write(path, Json::Obj(doc).to_string_pretty()) {
        Ok(()) => println!("wrote {path}"),
        Err(e) => println!("could not write {path}: {e}"),
    }
    println!("(target: simd fused+pool at 8 threads >= 4x the scalar three-pass;");
    println!(" feed the calibration block back into costmodel::HostKernels::calibrated)");
}

/// Simulated multi-GPU sharding: step time + scaling efficiency vs device
/// count for both strategies, written to `BENCH_multi_gpu.json`.
///
/// * data-parallel (weak scaling): each device runs a full replica on its
///   own batch shard; throughput = N·B·T / step; efficiency =
///   tps(N) / (N · tps(1)).  ZO's per-step comm is one seed broadcast + one
///   scalar all-reduce, so efficiency should stay ≈ 1.
/// * pipeline (model-parallel): blocks partitioned contiguously; per-device
///   PCIe traffic divides by N; speedup = tps(N) / tps(1), meaningful in
///   the comm-bound fp16-wire regime.
fn table_multi_gpu(hw: &Hardware) {
    println!("\n=== Multi-GPU: step time + scaling efficiency (fp16 wire/compute, NVLink) ===");
    println!(
        "{:<10} {:>2} | {:>10} {:>10} {:>6} {:>14} | {:>10} {:>8} {:>14}",
        "model", "N", "dp step", "dp tok/s", "eff", "dp bneck", "pipe step", "speedup", "pipe bneck"
    );
    let tokens = 2048.0;
    let mut rows: Vec<Json> = Vec::new();
    // Scaling headline in telemetry-snapshot form (same schema the engine
    // and simulator CLIs emit with `--metrics-out`).
    let reg = MetricsRegistry::new();
    for name in ["OPT-13B", "OPT-30B", "OPT-175B"] {
        let shape = opt_by_name(name).unwrap();
        let w = wl(&shape, 1, 2048, Codec::Fp16, ComputeMode::Fp16);
        let policy = Policy::default();
        let mut dp_tps1 = 0.0f64;
        let mut pipe_tps1 = 0.0f64;
        for n in [1usize, 2, 4, 8] {
            let cluster = Cluster::homogeneous(hw.clone(), n, Interconnect::nvlink());
            let costs = ClusterCost::new(&cluster, &w).expect("homogeneous cluster");

            let dp_plan = build_sharded_plan(
                shape.n_layers,
                SIM_STEPS,
                policy,
                &ShardSpec::data_parallel(n),
            );
            let (dp, _) = simulate(&dp_plan, &costs, policy);
            let dp_tps = n as f64 * tokens / dp.steady_step_s;
            if n == 1 {
                dp_tps1 = dp_tps;
            }
            let eff = dp_tps / (n as f64 * dp_tps1);

            let pipe_plan = build_sharded_plan(
                shape.n_layers,
                SIM_STEPS,
                policy,
                &ShardSpec::pipeline(n, ShardLayout::Contiguous),
            );
            let (pipe, _) = simulate(&pipe_plan, &costs, policy);
            let pipe_tps = tokens / pipe.steady_step_s;
            if n == 1 {
                pipe_tps1 = pipe_tps;
            }

            println!(
                "{:<10} {:>2} | {:>9.3}s {:>10.0} {:>6.2} {:>14} | {:>9.3}s {:>7.2}x {:>14}",
                name,
                n,
                dp.steady_step_s,
                dp_tps,
                eff,
                dp.bottleneck(),
                pipe.steady_step_s,
                pipe_tps / pipe_tps1,
                pipe.bottleneck()
            );
            let mut row = BTreeMap::new();
            row.insert("model".to_string(), Json::Str(name.to_string()));
            row.insert("devices".to_string(), Json::Num(n as f64));
            row.insert("dp_step_s".to_string(), Json::Num(dp.steady_step_s));
            row.insert("dp_tokens_per_s".to_string(), Json::Num(dp_tps));
            row.insert("dp_scaling_efficiency".to_string(), Json::Num(eff));
            row.insert("dp_bottleneck".to_string(), Json::Str(dp.bottleneck().to_string()));
            row.insert("pipeline_step_s".to_string(), Json::Num(pipe.steady_step_s));
            row.insert("pipeline_tokens_per_s".to_string(), Json::Num(pipe_tps));
            row.insert("pipeline_speedup".to_string(), Json::Num(pipe_tps / pipe_tps1));
            row.insert(
                "pipeline_bottleneck".to_string(),
                Json::Str(pipe.bottleneck().to_string()),
            );
            rows.push(Json::Obj(row));
            let nstr = n.to_string();
            reg.gauge_set(
                "sim_steady_step_s",
                &[("devices", nstr.as_str()), ("model", name), ("strategy", "dp")],
                dp.steady_step_s,
            );
            reg.gauge_set(
                "sim_steady_step_s",
                &[("devices", nstr.as_str()), ("model", name), ("strategy", "pipeline")],
                pipe.steady_step_s,
            );
        }
    }

    // Microbatching sweep: OPT-175B on 4 devices, M ∈ {1,2,4,8}, both
    // layouts, two-tier and (per-partition) three-tier on 24 GB-DRAM hosts.
    // `bubble` = 1 − Σ_d compute-busy / (N · makespan): the fraction of
    // device-time the cluster's compute streams sit idle — microbatching
    // exists to shrink it, until per-slice launch overhead pushes back.
    println!(
        "\n-- pipeline microbatching: OPT-175B x4, M sweep \
         (three-tier column: 24 GB DRAM per host, per-partition spills) --"
    );
    println!(
        "{:<11} {:>2} | {:>10} {:>7} {:>16} | {:>10} {:>7} {:>14}",
        "layout", "M", "pipe step", "bubble", "bneck", "pipe3 step", "bubble", "pipe3 bneck"
    );
    let shape = opt_by_name("OPT-175B").unwrap();
    let w = wl(&shape, 1, 2048, Codec::Fp16, ComputeMode::Fp16);
    let devices = 4usize;
    let cluster = Cluster::homogeneous(hw.clone(), devices, Interconnect::nvlink());
    let costs = ClusterCost::new(&cluster, &w).expect("homogeneous cluster");
    let gb = 1u64 << 30;
    let budgets =
        vec![MemoryBudget { hbm: 18 * gb, dram: 24 * gb, nvme: 2 << 40 }; devices];
    let mut sweep_rows: Vec<Json> = Vec::new();
    for layout in [ShardLayout::Contiguous, ShardLayout::Cyclic] {
        let plans = plan_three_tier_partitioned(
            &w,
            &budgets,
            layout,
            3,
            4,
            2,
            hw,
            SpillPlacement::Trailing,
        );
        let spilled: Vec<usize> = plans.iter().map(|p| p.spilled_blocks).collect();
        let tiers: Vec<DeviceTier> = plans.iter().map(|p| p.device_tier()).collect();
        let policy3 = Policy {
            tiering: Tiering::ThreeTier,
            spilled: spilled.iter().sum(),
            dram_slots: 4,
            ..Policy::default()
        };
        for m in [1usize, 2, 4, 8] {
            let spec = ShardSpec::pipeline_microbatched(devices, layout, m);
            let policy = Policy::default();
            let plan = build_sharded_plan(shape.n_layers, SIM_STEPS, policy, &spec);
            let (s2, _) = simulate(&plan, &costs, policy);
            let bubble2 = 1.0 - s2.busy_of("compute") / (devices as f64 * s2.makespan);

            let plan3 = build_sharded_plan_tiered(
                shape.n_layers,
                SIM_STEPS,
                policy3,
                &spec,
                Some(&tiers),
                None,
            );
            let (s3, _) = simulate(&plan3, &costs, policy3);
            let bubble3 = 1.0 - s3.busy_of("compute") / (devices as f64 * s3.makespan);

            let lname = match layout {
                ShardLayout::Contiguous => "contiguous",
                ShardLayout::Cyclic => "cyclic",
            };
            println!(
                "{:<11} {:>2} | {:>9.3}s {:>6.1}% {:>16} | {:>9.3}s {:>6.1}% {:>14}",
                lname,
                m,
                s2.steady_step_s,
                100.0 * bubble2,
                s2.bottleneck(),
                s3.steady_step_s,
                100.0 * bubble3,
                s3.bottleneck()
            );
            let mut row = BTreeMap::new();
            row.insert("model".to_string(), Json::Str("OPT-175B".to_string()));
            row.insert("devices".to_string(), Json::Num(devices as f64));
            row.insert("layout".to_string(), Json::Str(lname.to_string()));
            row.insert("microbatches".to_string(), Json::Num(m as f64));
            row.insert("pipeline_step_s".to_string(), Json::Num(s2.steady_step_s));
            row.insert("pipeline_bubble".to_string(), Json::Num(bubble2));
            row.insert("pipeline_bottleneck".to_string(), Json::Str(s2.bottleneck().to_string()));
            row.insert("pipeline3_step_s".to_string(), Json::Num(s3.steady_step_s));
            row.insert("pipeline3_bubble".to_string(), Json::Num(bubble3));
            row.insert(
                "pipeline3_bottleneck".to_string(),
                Json::Str(s3.bottleneck().to_string()),
            );
            row.insert(
                "pipeline3_spilled_per_device".to_string(),
                Json::Arr(spilled.iter().map(|&s| Json::Num(s as f64)).collect()),
            );
            sweep_rows.push(Json::Obj(row));
        }
    }

    // Heterogeneous sweep: mixed A100/RTX4090 pipelines.  Quantifies (a)
    // the slow-host bottleneck — a balanced split is paced by the slowest
    // host's per-step round time regardless of device order — and (b) the
    // bottleneck-aware layout hint, which hands the faster hosts more
    // blocks (`shard::weighted_contiguous_owners` over
    // `shard::bottleneck_weights`) and claws part of the loss back.
    println!(
        "\n-- heterogeneous: OPT-30B x4 pipeline, balanced vs weighted placement \
         (fp16 wire/compute, NVLink) --"
    );
    println!(
        "{:<11} | {:>10} {:>12} | {:>10} {:>14} {:>7}",
        "cluster", "balanced", "bneck", "weighted", "blocks/device", "hint"
    );
    let shape30 = opt_by_name("OPT-30B").unwrap();
    let w30 = wl(&shape30, 1, 2048, Codec::Fp16, ComputeMode::Fp16);
    let a100 = Hardware::a100_pcie4();
    let g4090 = Hardware::rtx4090_pcie4();
    let scenarios: Vec<(&str, Vec<Hardware>)> = vec![
        ("a100x4", vec![a100.clone(); 4]),
        ("fast-first", vec![a100.clone(), a100.clone(), g4090.clone(), g4090.clone()]),
        ("slow-first", vec![g4090.clone(), g4090.clone(), a100.clone(), a100.clone()]),
    ];
    let het_devices = 4usize;
    let mut het_rows: Vec<Json> = Vec::new();
    let mut baseline_step = 0.0f64;
    for (label, devs) in &scenarios {
        let cluster = Cluster::heterogeneous(devs.clone(), Interconnect::nvlink());
        let costs = ClusterCost::new(&cluster, &w30).expect("mixed clusters price");
        let spec = ShardSpec::pipeline(het_devices, ShardLayout::Contiguous);
        let policy = Policy::default();
        let balanced = build_sharded_plan(shape30.n_layers, SIM_STEPS, policy, &spec);
        let (sb, _) = simulate(&balanced, &costs, policy);
        let weights = bottleneck_weights(&costs, het_devices);
        let owners = weighted_contiguous_owners(shape30.n_layers, &weights);
        let hinted = build_sharded_plan_tiered(
            shape30.n_layers,
            SIM_STEPS,
            policy,
            &spec,
            None,
            Some(&owners),
        );
        let (sw, _) = simulate(&hinted, &costs, policy);
        let counts: Vec<usize> =
            blocks_per_device_of(&owners, het_devices).iter().map(|v| v.len()).collect();
        if *label == "a100x4" {
            baseline_step = sb.steady_step_s;
        }
        println!(
            "{:<11} | {:>9.3}s {:>12} | {:>9.3}s {:>14} {:>6.2}x",
            label,
            sb.steady_step_s,
            sb.bottleneck(),
            sw.steady_step_s,
            format!("{counts:?}"),
            sb.steady_step_s / sw.steady_step_s,
        );
        let mut row = BTreeMap::new();
        row.insert("model".to_string(), Json::Str("OPT-30B".to_string()));
        row.insert("cluster".to_string(), Json::Str(label.to_string()));
        row.insert(
            "devices".to_string(),
            Json::Arr(devs.iter().map(|h| Json::Str(h.name.clone())).collect()),
        );
        row.insert("balanced_step_s".to_string(), Json::Num(sb.steady_step_s));
        row.insert("balanced_bottleneck".to_string(), Json::Str(sb.bottleneck().to_string()));
        row.insert(
            "balanced_vs_homogeneous".to_string(),
            Json::Num(if baseline_step > 0.0 { sb.steady_step_s / baseline_step } else { 1.0 }),
        );
        row.insert("weighted_step_s".to_string(), Json::Num(sw.steady_step_s));
        row.insert(
            "weighted_blocks_per_device".to_string(),
            Json::Arr(counts.iter().map(|&c| Json::Num(c as f64)).collect()),
        );
        row.insert(
            "layout_hint_speedup".to_string(),
            Json::Num(sb.steady_step_s / sw.steady_step_s),
        );
        het_rows.push(Json::Obj(row));
    }

    // Per-host DRAM budgets on the mixed cluster: server hosts get 48 GB
    // (their 12-block partitions stay fully DDR-resident), the consumer
    // hosts 8 GB (most of their partition spills) — each partition spills
    // against its *own* budget and stages through its *own* window depth.
    let mixed = vec![a100.clone(), a100.clone(), g4090.clone(), g4090.clone()];
    let cluster = Cluster::heterogeneous(mixed.clone(), Interconnect::nvlink());
    let costs = ClusterCost::new(&cluster, &w30).expect("mixed clusters price");
    let gbb = 1u64 << 30;
    let het_budgets: Vec<MemoryBudget> = mixed
        .iter()
        .enumerate()
        .map(|(d, hw)| MemoryBudget {
            hbm: hw.hbm_capacity,
            dram: if d < 2 { 48 * gbb } else { 8 * gbb },
            nvme: 2 << 40,
        })
        .collect();
    let per30 = zo2::shard::blocks_per_device(ShardLayout::Contiguous, shape30.n_layers, 4);
    let counts30: Vec<usize> = per30.iter().map(|v| v.len()).collect();
    let hws30: Vec<&Hardware> = mixed.iter().collect();
    let plans30 = plan_three_tier_owned(
        &w30,
        &het_budgets,
        &counts30,
        3,
        4,
        2,
        &hws30,
        SpillPlacement::Trailing,
    );
    let tiers30: Vec<DeviceTier> = plans30.iter().map(|p| p.device_tier()).collect();
    let policy30 = Policy {
        tiering: Tiering::ThreeTier,
        spilled: tiers30.iter().map(|t| t.spilled).sum(),
        ..Policy::default()
    };
    let spec30 = ShardSpec::pipeline(4, ShardLayout::Contiguous);
    let plan30 = build_sharded_plan_tiered(
        shape30.n_layers,
        SIM_STEPS,
        policy30,
        &spec30,
        Some(&tiers30),
        None,
    );
    let (s30, _) = simulate(&plan30, &costs, policy30);
    let spilled30: Vec<usize> = tiers30.iter().map(|t| t.spilled).collect();
    println!(
        "  three-tier, per-host budgets [48,48,8,8] GB: step {:.3}s ({}), \
         spilled per device {:?}",
        s30.steady_step_s,
        s30.bottleneck(),
        spilled30,
    );
    let mut row = BTreeMap::new();
    row.insert("model".to_string(), Json::Str("OPT-30B".to_string()));
    row.insert("cluster".to_string(), Json::Str("fast-first-three-tier".to_string()));
    row.insert(
        "dram_gb_per_host".to_string(),
        Json::Arr(vec![48.0, 48.0, 8.0, 8.0].into_iter().map(Json::Num).collect()),
    );
    row.insert("step_s".to_string(), Json::Num(s30.steady_step_s));
    row.insert("bottleneck".to_string(), Json::Str(s30.bottleneck().to_string()));
    row.insert(
        "spilled_per_device".to_string(),
        Json::Arr(spilled30.iter().map(|&s| Json::Num(s as f64)).collect()),
    );
    het_rows.push(Json::Obj(row));

    let mut doc = BTreeMap::new();
    doc.insert("bench".to_string(), Json::Str("multi_gpu".to_string()));
    doc.insert("wire".to_string(), Json::Str("fp16".to_string()));
    doc.insert("link".to_string(), Json::Str("NVLink".to_string()));
    doc.insert("rows".to_string(), Json::Arr(rows));
    doc.insert("microbatch_sweep".to_string(), Json::Arr(sweep_rows));
    doc.insert("microbatch_sweep_dram_gb_per_host".to_string(), Json::Num(24.0));
    doc.insert("heterogeneous_sweep".to_string(), Json::Arr(het_rows));
    doc.insert("metrics".to_string(), reg.snapshot_json());
    let path = "BENCH_multi_gpu.json";
    match std::fs::write(path, Json::Obj(doc).to_string_pretty()) {
        Ok(()) => println!("wrote {path}"),
        Err(e) => println!("could not write {path}: {e}"),
    }
    println!("(dp: weak scaling, efficiency ~1 expected — ZO ships one scalar per step;");
    println!(" pipeline: wins only where PCIe is the constraint, layout matters;");
    println!(" microbatching shrinks the per-step bubble at M>1 — most on cyclic layouts,");
    println!(" where every block boundary crosses the link)");
}

/// Autotuner grid: model scale × device count × DDR budget, each cell tuned
/// with the same seed, then the winner re-priced through a fresh oracle call
/// — the predicted-vs-simulated error column is the autotuner's replay
/// guarantee made visible (it must be ~0 by construction).
fn table_tune(hw: &Hardware) {
    println!("\n== autotuner grid (tune: beam+anneal over the policy knobs, fp16 wire) ==");
    let opts = TuneOpts { seed: 0, beam: 2, anneal_iters: 16, topk: 3 };
    let gb = 1u64 << 30;
    let mut rows: Vec<Json> = Vec::new();
    for model in ["OPT-13B", "OPT-30B", "OPT-175B"] {
        let shape = opt_by_name(model).unwrap();
        for devices in [1usize, 2, 4] {
            for dram_gb in [24u64, 64] {
                let wl = Workload {
                    shape: shape.clone(),
                    batch: 1,
                    seq: 2048,
                    wire: Codec::Fp16,
                    compute: ComputeMode::Fp16,
                };
                let sc = Scenario {
                    wl,
                    hw: vec![hw.clone(); devices],
                    links: vec![Interconnect::nvlink(); devices],
                    dram_budget_bytes: Some(vec![dram_gb * gb; devices]),
                    steps: SIM_STEPS,
                    param_bytes: 2,
                };
                let space = SearchSpace::default_for(devices, true);
                let result = tune(&sc, &space, &opts).unwrap();
                let mut row = BTreeMap::new();
                row.insert("model".to_string(), Json::Str(model.to_string()));
                row.insert("devices".to_string(), Json::Num(devices as f64));
                row.insert("dram_gb".to_string(), Json::Num(dram_gb as f64));
                row.insert("explored".to_string(), Json::Num(result.explored as f64));
                row.insert("pruned".to_string(), Json::Num(result.pruned.len() as f64));
                match &result.best {
                    Some(best) => {
                        // Replay check: a fresh oracle call on the winning
                        // candidate must land on the predicted step time.
                        let resim = match evaluate(&sc, &best.cand) {
                            Verdict::Feasible { step_s, .. } => step_s,
                            Verdict::Infeasible { reason } => {
                                panic!("{model} x{devices}: best became infeasible: {reason}")
                            }
                        };
                        let err = (resim - best.step_s).abs();
                        assert!(
                            err < 1e-9,
                            "{model} x{devices} @{dram_gb}GB: predicted {} vs resim {resim}",
                            best.step_s
                        );
                        println!(
                            "  {model:<9} x{devices} @{dram_gb:>2}GB: step {:.3}s ({}) | {} | \
                             err {err:.1e} | explored {}/{} ({} pruned)",
                            best.step_s,
                            best.bottleneck,
                            best.cand.key(),
                            result.explored,
                            result.space_size,
                            result.pruned.len(),
                        );
                        row.insert("config".to_string(), Json::Str(best.cand.key()));
                        row.insert("predicted_step_s".to_string(), Json::Num(best.step_s));
                        row.insert("resim_step_s".to_string(), Json::Num(resim));
                        row.insert("abs_err_s".to_string(), Json::Num(err));
                    }
                    None => {
                        println!(
                            "  {model:<9} x{devices} @{dram_gb:>2}GB: no feasible config \
                             ({} explored, all pruned)",
                            result.explored,
                        );
                        row.insert("config".to_string(), Json::Null);
                    }
                }
                rows.push(Json::Obj(row));
            }
        }
    }
    let mut doc = BTreeMap::new();
    doc.insert("bench".to_string(), Json::Str("tune".to_string()));
    doc.insert("wire".to_string(), Json::Str("fp16".to_string()));
    doc.insert("objective".to_string(), Json::Str("steady_step_s".to_string()));
    doc.insert("tune_seed".to_string(), Json::Num(opts.seed as f64));
    doc.insert("rows".to_string(), Json::Arr(rows));
    let path = "BENCH_tune.json";
    match std::fs::write(path, Json::Obj(doc).to_string_pretty()) {
        Ok(()) => println!("wrote {path}"),
        Err(e) => println!("could not write {path}: {e}"),
    }
    println!("(the error column is the replay contract: tune prices candidates with the");
    println!(" same planner + simulator path `simulate --config tuned.json` replays)");
}

fn main() {
    let filter = std::env::args().nth(1).unwrap_or_default();
    let hw = Hardware::a100_pcie4();
    let run = |name: &str| filter.is_empty() || filter == "--bench" || name.contains(&filter);

    println!("ZO2 paper-table regeneration (simulated {}, see DESIGN.md)", hw.name);
    if run("fig1") {
        fig1_memory(&hw);
    }
    if run("table2") {
        table2_main(&hw);
    }
    if run("table4") {
        table4_ablation(&hw);
    }
    if run("table5") {
        table5_amp(&hw);
    }
    if run("table6") {
        table6_batch(&hw);
    }
    if run("table7") {
        table7_seqlen(&hw);
    }
    if run("fig3") {
        fig3_comm(&hw);
    }
    if run("fig4") {
        fig4_timeline(&hw);
    }
    if run("ablations") {
        ablations(&hw);
    }
    if run("disk_tier") {
        table_disk_tier(&hw);
    }
    if run("host_kernels") {
        table_host_kernels(&hw);
    }
    if run("multi_gpu") {
        table_multi_gpu(&hw);
    }
    if run("tune") {
        table_tune(&hw);
    }
    println!("\n(Table 3 is regenerated by `cargo run --release --example accuracy_parity`");
    println!(" and asserted bit-exactly by `cargo test --test parity`.)");
}
