//! Microbenchmarks of the L3 hot paths + real↔sim calibration.
//!
//!     cargo bench --bench micro
//!
//! Sections:
//!   codecs       — precision encode/decode throughput (upload/offload path)
//!   rng          — Gaussian fill throughput (z generation path)
//!   sched        — scheduler plan+simulate overhead (must be negligible)
//!   real-step    — real tiny-model step wallclock by mode (overlap vs seq)
//!   calibration  — measured per-block compute feeds the simulator; its
//!                  real-mode prediction must be within band of measurement

use std::time::Instant;

use zo2::data::SyntheticCorpus;
use zo2::precision::Codec;
use zo2::rng::GaussianRng;
use zo2::runtime::Runtime;
use zo2::sched::{build_plan, simulate, CostProvider, Module, Policy};
use zo2::util::stats::bench;
use zo2::zo::{RunMode, Zo2Engine, Zo2Options, ZoConfig};

fn bench_codecs() {
    println!("\n=== codecs (1M f32 elements) ===");
    let mut rng = GaussianRng::new(1, 1);
    let mut xs = vec![0.0f32; 1 << 20];
    rng.fill_gaussian(&mut xs);
    for codec in [Codec::F32, Codec::Bf16, Codec::Fp16, Codec::Fp8E4M3] {
        let mut buf = Vec::new();
        let enc = bench(2, 8, || codec.encode_into(&xs, &mut buf));
        let payload = buf.len();
        let mut out = vec![0.0f32; xs.len()];
        let dec = bench(2, 8, || codec.decode_into(&buf, &mut out));
        let gbs = |s: f64| (xs.len() * 4) as f64 / s / 1e9;
        println!(
            "{:>5}: encode {:>7.2} GB/s  decode {:>7.2} GB/s  (wire {:.0}% of fp32)",
            codec.name(),
            gbs(enc.percentile(50.0)),
            gbs(dec.percentile(50.0)),
            100.0 * payload as f64 / (xs.len() * 4) as f64
        );
    }
}

fn bench_rng() {
    println!("\n=== rng (z generation, 1M gaussians) ===");
    let mut z = vec![0.0f32; 1 << 20];
    let mut rng = GaussianRng::new(7, 3);
    let s = bench(2, 8, || rng.fill_gaussian(&mut z));
    println!(
        "fill_gaussian: {:.1} M elems/s ({:.2} ms per 1M)",
        (z.len() as f64 / s.percentile(50.0)) / 1e6,
        s.percentile(50.0) * 1e3
    );
}

fn bench_sched() {
    println!("\n=== scheduler (plan + simulate, 96 blocks x 4 steps) ===");
    struct C;
    impl CostProvider for C {
        fn upload_s(&self) -> f64 {
            0.01
        }
        fn offload_s(&self) -> f64 {
            0.01
        }
        fn compute_s(&self, _m: Module) -> f64 {
            0.02
        }
        fn update_s(&self) -> f64 {
            0.001
        }
    }
    let p = Policy::default();
    let s = bench(3, 20, || {
        let plan = build_plan(96, 4, p);
        let _ = simulate(&plan, &C, p);
    });
    println!(
        "plan+simulate: {:.2} ms median (coordinator overhead per simulated run)",
        s.percentile(50.0) * 1e3
    );
}

fn bench_real_step() {
    println!("\n=== real tiny-model step (PJRT CPU) ===");
    let Ok(rt) = Runtime::load_config("tiny") else {
        println!("(skipped: run `make artifacts`)");
        return;
    };
    rt.compile_all().unwrap();
    let m = rt.manifest();
    let (b, t, v) = (m.config.batch, m.config.seq_len, m.config.vocab);
    let mut corpus = SyntheticCorpus::new(v, 5);
    let ids = corpus.sample(b, t).ids;

    for (label, mode) in [("sequential", RunMode::Sequential), ("overlapped", RunMode::Overlapped)] {
        let rt = Runtime::load_config("tiny").unwrap();
        rt.compile_all().unwrap();
        let mut e = Zo2Engine::new(
            rt,
            ZoConfig::default(),
            Zo2Options { run_mode: mode, ..Default::default() },
        )
        .unwrap();
        // warmup
        for _ in 0..3 {
            e.train_step(&ids).unwrap();
        }
        let t0 = Instant::now();
        let iters = 10;
        for _ in 0..iters {
            e.train_step(&ids).unwrap();
        }
        let per = t0.elapsed().as_secs_f64() / iters as f64;
        println!(
            "{label:>11}: {:.2} ms/step  ({:.0} tokens/s)",
            per * 1e3,
            (b * t) as f64 / per
        );
        // The real engine's own Fig. 4 trace (tiny scale): the measured
        // counterpart of the simulated timelines in paper_tables -- fig4.
        println!("{}", e.last_timeline.to_ascii_gantt(80));
    }
}

fn bench_calibration() {
    println!("\n=== calibration: sim prediction vs real sequential step ===");
    let Ok(rt) = Runtime::load_config("tiny") else {
        println!("(skipped: run `make artifacts`)");
        return;
    };
    rt.compile_all().unwrap();
    let m = rt.manifest();
    let (b, t, v) = (m.config.batch, m.config.seq_len, m.config.vocab);
    let n_blocks = m.config.n_layers;
    let block_sz = m.block.size;
    let mut corpus = SyntheticCorpus::new(v, 5);
    let ids = corpus.sample(b, t).ids;

    // Measure the real per-phase costs on this machine.
    let mut e = Zo2Engine::new(
        rt,
        ZoConfig::default(),
        Zo2Options { run_mode: RunMode::Sequential, ..Default::default() },
    )
    .unwrap();
    for _ in 0..3 {
        e.train_step(&ids).unwrap();
    }
    let t0 = Instant::now();
    let iters = 10;
    for _ in 0..iters {
        e.train_step(&ids).unwrap();
    }
    let real_step = t0.elapsed().as_secs_f64() / iters as f64;

    // Fit a measured CostProvider from the engine's own timeline.
    let tl = &e.last_timeline;
    let avg = |prefix: &str| {
        let evs: Vec<f64> = tl
            .events
            .iter()
            .filter(|ev| ev.label.starts_with(prefix))
            .map(|ev| ev.end - ev.start)
            .collect();
        evs.iter().sum::<f64>() / evs.len().max(1) as f64
    };
    struct Measured {
        u: f64,
        c: f64,
        o: f64,
    }
    impl CostProvider for Measured {
        fn upload_s(&self) -> f64 {
            self.u
        }
        fn offload_s(&self) -> f64 {
            self.o
        }
        fn compute_s(&self, m: Module) -> f64 {
            match m {
                Module::Block(_) => self.c,
                _ => self.c * 0.5, // embed/head measured separately below
            }
        }
        fn update_s(&self) -> f64 {
            self.c * 0.1
        }
    }
    let costs = Measured { u: avg("U"), c: avg("C"), o: avg("O") };
    let policy = Policy { overlap: false, ..Policy::default() };
    let plan = build_plan(n_blocks, 1, policy);
    let (sched, _) = simulate(&plan, &costs, policy);
    // The sim covers blocks only; embed/head/ids overhead remains real.
    let blocks_real: f64 = tl.events.iter().map(|ev| ev.end - ev.start).sum();
    let blocks_sim: f64 = sched.makespan
        - 2.0 * costs.compute_s(Module::Embed); // subtract the embed+head placeholders
    println!(
        "real step {:.2} ms (blocks portion {:.2} ms) | sim blocks {:.2} ms | block bucket {} elems x{}",
        real_step * 1e3,
        blocks_real * 1e3,
        blocks_sim * 1e3,
        block_sz,
        n_blocks
    );
    let rel = (blocks_sim - blocks_real).abs() / blocks_real;
    println!(
        "sim-vs-real relative error on the block pipeline: {:.1}% {}",
        rel * 100.0,
        if rel < 0.35 { "(within calibration band)" } else { "(OUT OF BAND)" }
    );
}

fn main() {
    let filter = std::env::args().nth(1).unwrap_or_default();
    let run = |name: &str| filter.is_empty() || filter == "--bench" || name.contains(&filter);
    if run("codecs") {
        bench_codecs();
    }
    if run("rng") {
        bench_rng();
    }
    if run("sched") {
        bench_sched();
    }
    if run("real-step") {
        bench_real_step();
    }
    if run("calibration") {
        bench_calibration();
    }
}
