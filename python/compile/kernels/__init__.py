from .zo_dual_matmul import zo_dual_matmul, choose_block, vmem_bytes
from .zo_update import zo_update
from . import ref

__all__ = ["zo_dual_matmul", "zo_update", "choose_block", "vmem_bytes", "ref"]
