"""Pure-jnp oracles for the Pallas kernels — the CORE correctness signal.

These are deliberately the most naive possible formulations (materialise the
perturbed weights, call jnp.dot) so that any tiling/accumulation/revisit bug
in the kernels shows up as a numeric mismatch in pytest.
"""

import jax.numpy as jnp


def zo_dual_matmul_ref(xp, xm, w, z, eps):
    wp = w + eps * z
    wm = w - eps * z
    return jnp.dot(xp, wp), jnp.dot(xm, wm)


def zo_update_ref(bucket, z, lr, g):
    return bucket - (lr * g) * z
