"""L1 Pallas kernel: fused dual perturbed matmul — ZO2's core insight on TPU.

The paper's system-level trick is "transfer each weight once, use it for both
forward passes" (CPU->GPU over PCIe).  At the kernel level the same trick
applies one memory tier down: each weight tile (and its Gaussian direction
tile `z`) is streamed HBM->VMEM **once** and serves *both* perturbed matmuls

    y+ = x+ @ (W + eps*z)
    y- = x- @ (W - eps*z)

halving weight traffic versus running two independent perturbed matmuls, and
never materialising W+eps*z / W-eps*z in HBM (they exist only as VMEM tiles).

Grid is (M/bm, N/bn, K/bk) with the K axis innermost; partial products are
accumulated directly into the output tiles (revisited across the K axis),
fp32 accumulate — the MXU-friendly schedule.  Block sizes are chosen by
`choose_block` to divide the dims exactly: 128-aligned tiles at paper scale,
whole-array tiles for the tiny test configs.

interpret=True everywhere: the CPU PJRT plugin cannot run Mosaic
custom-calls; real-TPU perf is estimated from the VMEM footprint + MXU
utilisation of these block shapes in DESIGN.md / EXPERIMENTS.md §Perf.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Tile caps. Two profiles:
#  - CPU/interpret (what we AOT for the PJRT CPU runtime): large tiles — the
#    grid-step overhead of interpret mode dominates, and VMEM doesn't bind.
#  - TPU: 128-aligned tiles sized so the (x+, x-, w, z, out+, out-) working
#    set stays well under a core's ~16 MB VMEM; `vmem_bytes` below reports
#    the footprint used for the DESIGN.md §Perf roofline estimate.
BM_CAP = 512
BN_CAP = 1024
BK_CAP = 2048
TPU_BM_CAP = 256
TPU_BN_CAP = 512
TPU_BK_CAP = 512


def choose_block(dim: int, cap: int) -> int:
    """Largest power-of-two-ish tile <= cap that divides `dim` exactly."""
    if dim <= cap:
        return dim
    for c in (cap, 1024, 512, 384, 256, 192, 128, 64, 32, 16, 8, 4, 2):
        if c <= cap and dim % c == 0:
            return c
    return dim  # prime-ish dim: single tile


def _kernel(xp_ref, xm_ref, w_ref, z_ref, eps_ref, op_ref, om_ref, *, nk):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        op_ref[...] = jnp.zeros_like(op_ref)
        om_ref[...] = jnp.zeros_like(om_ref)

    eps = eps_ref[0, 0]
    w = w_ref[...]
    ez = eps * z_ref[...]
    # One VMEM-resident (w, z) tile serves both perturbed products.
    op_ref[...] += jnp.dot(xp_ref[...], w + ez, preferred_element_type=jnp.float32)
    om_ref[...] += jnp.dot(xm_ref[...], w - ez, preferred_element_type=jnp.float32)


def zo_dual_matmul(xp, xm, w, z, eps):
    """(y+, y-) = (xp @ (w + eps*z), xm @ (w - eps*z)).

    xp, xm: [M, K] f32;  w, z: [K, N] f32;  eps: scalar f32 (traced).
    """
    m, k = xp.shape
    k2, n = w.shape
    assert k == k2 and xm.shape == xp.shape and z.shape == w.shape
    # Storage may be low-bit (AMP mode); the MXU path computes in f32.
    xp, xm, w, z = (a.astype(jnp.float32) for a in (xp, xm, w, z))
    bm = choose_block(m, BM_CAP)
    bn = choose_block(n, BN_CAP)
    bk = choose_block(k, BK_CAP)
    grid = (m // bm, n // bn, k // bk)
    eps2d = jnp.reshape(eps.astype(jnp.float32), (1, 1))

    out_shape = [
        jax.ShapeDtypeStruct((m, n), jnp.float32),
        jax.ShapeDtypeStruct((m, n), jnp.float32),
    ]
    return pl.pallas_call(
        functools.partial(_kernel, nk=grid[2]),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),   # x+
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),   # x-
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),   # w
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),   # z
            pl.BlockSpec((1, 1), lambda i, j, kk: (0, 0)),      # eps
        ],
        out_specs=[
            pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
            pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        ],
        out_shape=out_shape,
        interpret=True,
    )(xp, xm, w, z, eps2d)


def vmem_bytes(m, n, k) -> int:
    """VMEM working set of one grid step under the TPU tile profile."""
    bm, bn, bk = (choose_block(m, TPU_BM_CAP), choose_block(n, TPU_BN_CAP),
                  choose_block(k, TPU_BK_CAP))
    # x+ x- tiles, w z tiles, two fp32 accum tiles
    return 4 * (2 * bm * bk + 2 * bk * bn + 2 * bm * bn)
