"""L1 Pallas kernel: fused ZO parameter update over a flat bucket.

    bucket' = bucket - (lr * g) * z

`g` is the scalar projected gradient (paper Eq. 2) and `z` the Gaussian
direction replayed from the managed RNG state, so the true gradient
`g*z` is never materialised (paper §4.1 point 4) — the update streams the
bucket through VMEM tile by tile with zero extra HBM buffers.

This same kernel is used (a) inside every fused per-module *step* executable
(deferred update, paper §5.4) and (b) in the standalone `update_*` artifacts
used for the final flush after the last training step — one code path, so the
flush is bit-identical to the in-step update by construction.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BP_CAP = 1 << 20  # 1M f32 per tile => ~12 MB VMEM for (w, z, out)


def _kernel(w_ref, z_ref, s_ref, o_ref):
    # The barrier pins the (mul, sub) rounding: without it, XLA may contract
    # `w - s*z` into an FMA in one embedding executable but not another,
    # producing 1-ulp divergence between the fused deferred update and the
    # standalone flush — which would break MeZO≡ZO2 bit-parity.
    delta = jax.lax.optimization_barrier(s_ref[0] * z_ref[...])
    o_ref[...] = w_ref[...] - delta


def pick_tile(p: int, cap: int, max_grid: int = 64) -> int:
    """Largest tile that divides `p` with a small grid.

    Flat bucket sizes are arbitrary (e.g. 7,087,872 for the gpt2-100m
    block), so walking the *grid* count up and taking the first divisor
    keeps the number of pallas grid steps tiny.  If no small grid exists
    (prime-ish sizes), fall back to a single whole-bucket tile — on the CPU
    interpret path VMEM does not bind; the TPU deployment note in DESIGN.md
    covers padding strategies for that case.
    """
    if p <= cap:
        return p
    for g in range(2, max_grid + 1):
        if p % g == 0 and p // g <= cap:
            return p // g
    return p  # single tile


def zo_update(bucket, z, lr, g):
    """Elementwise bucket update; bucket/z are flat f32 [P]."""
    (p,) = bucket.shape
    assert z.shape == (p,)
    bucket = bucket.astype(jnp.float32)
    z = z.astype(jnp.float32)

    bp = pick_tile(p, BP_CAP)
    scale = jnp.reshape((lr * g).astype(jnp.float32), (1,))
    return pl.pallas_call(
        _kernel,
        grid=(p // bp,),
        in_specs=[
            pl.BlockSpec((bp,), lambda i: (i,)),
            pl.BlockSpec((bp,), lambda i: (i,)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((bp,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((p,), jnp.float32),
        interpret=True,
    )(bucket, z, scale)
