"""AOT compile path: lower every module executable to HLO *text* + manifest.

Run once by `make artifacts`; python never appears on the training path.

Interchange format is HLO **text**, not a serialized HloModuleProto: jax
>= 0.5 emits protos with 64-bit instruction ids which the rust side's
xla_extension 0.5.1 rejects (`proto.id() <= INT_MAX`); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Per config we emit, under artifacts/<name>/:

  embed_step / block_step / head_step   fused deferred-update + dual-forward
  embed_fwd  / block_fwd  / head_eval   single-forward eval path
  update_embed / update_block / update_head   final-flush updates
  manifest.json                         config + bucket layouts + signatures
  golden/                               (tiny configs) input/output vectors
                                        for the rust runtime integration test
"""

import argparse
import functools
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import model as M
from .configs import (CONFIGS, ModelConfig, block_layout, embed_layout,
                      head_layout, layout_offsets, layout_size, total_params)

F32 = jnp.float32
I32 = jnp.int32
U32 = jnp.uint32


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec(shape, dtype=F32):
    return jax.ShapeDtypeStruct(shape, dtype)


def executables(cfg: ModelConfig):
    """name -> (fn, arg specs). Argument order is the rust-side ABI."""
    pe = layout_size(embed_layout(cfg))
    pb = layout_size(block_layout(cfg))
    ph = layout_size(head_layout(cfg))
    b, t, d, v = cfg.batch, cfg.seq_len, cfg.d_model, cfg.vocab
    sc = _spec(())          # f32 scalar
    ids = _spec((b, t), I32)
    h = _spec((b, t, d))

    key = _spec((2,), U32)  # threefry key data (the managed RNG state)

    def step_args(p, *extra):
        # bucket, key_prev, g_prev, lr, key_cur, eps, inputs...
        return (_spec((p,)), key, sc, sc, key, sc) + extra

    return {
        "embed_step": (functools.partial(M.embed_step, cfg), step_args(pe, ids)),
        "block_step": (functools.partial(M.block_step, cfg), step_args(pb, h, h)),
        "head_step": (functools.partial(M.head_step, cfg), step_args(ph, h, h, ids)),
        "embed_fwd": (functools.partial(M.embed_fwd, cfg), (_spec((pe,)), ids)),
        "block_fwd": (functools.partial(M.block_fwd, cfg), (_spec((pb,)), h)),
        "head_eval": (functools.partial(M.head_eval, cfg), (_spec((ph,)), h, ids)),
        "update_embed": (M.update_bucket, (_spec((pe,)), key, sc, sc)),
        "update_block": (M.update_bucket, (_spec((pb,)), key, sc, sc)),
        "update_head": (M.update_bucket, (_spec((ph,)), key, sc, sc)),
    }


def _layout_json(layout):
    return [
        {"name": n, "offset": off, "shape": list(shape)}
        for n, off, shape in layout_offsets(layout)
    ]


def manifest(cfg: ModelConfig, arts):
    return {
        "config": {
            "name": cfg.name, "d_model": cfg.d_model, "n_heads": cfg.n_heads,
            "n_layers": cfg.n_layers, "vocab": cfg.vocab,
            "seq_len": cfg.seq_len, "batch": cfg.batch,
            "ffn_mult": cfg.ffn_mult, "total_params": total_params(cfg),
        },
        "buckets": {
            "embed": {"size": layout_size(embed_layout(cfg)),
                      "layout": _layout_json(embed_layout(cfg))},
            "block": {"size": layout_size(block_layout(cfg)),
                      "layout": _layout_json(block_layout(cfg))},
            "head": {"size": layout_size(head_layout(cfg)),
                     "layout": _layout_json(head_layout(cfg))},
        },
        "artifacts": {name: f"{name}.hlo.txt" for name in arts},
    }


# --- golden vectors ---------------------------------------------------------

def _dump_bin(path, arr):
    a = np.asarray(arr)
    dt = {"i": np.int32, "u": np.uint32}.get(a.dtype.kind, np.float32)
    a.astype(dt).tofile(path)


def emit_goldens(cfg: ModelConfig, outdir: str):
    """Concrete input/output pairs the rust runtime test replays bit-for-bit."""
    gdir = os.path.join(outdir, "golden")
    os.makedirs(gdir, exist_ok=True)
    rng = np.random.RandomState(0)
    exes = executables(cfg)
    cases = []
    for name in ("embed_step", "block_step", "head_step", "block_fwd",
                 "head_eval", "update_block"):
        fn, specs = exes[name]
        args = []
        for s in specs:
            if s.dtype == I32:
                args.append(rng.randint(0, cfg.vocab, size=s.shape).astype(np.int32))
            elif s.dtype == U32:
                args.append(rng.randint(0, 2**31, size=s.shape).astype(np.uint32))
            elif s.shape == ():
                args.append(np.float32(rng.uniform(0.001, 0.01)))
            else:
                args.append(rng.normal(0, 0.05, size=s.shape).astype(np.float32))
        outs = jax.jit(fn)(*args)
        if not isinstance(outs, (tuple, list)):
            outs = (outs,)
        case = {"exe": name, "inputs": [], "outputs": []}
        for i, a in enumerate(args):
            f = f"{name}_in{i}.bin"
            _dump_bin(os.path.join(gdir, f), a)
            a = np.asarray(a)
            dt = {"i": "i32", "u": "u32"}.get(a.dtype.kind, "f32")
            case["inputs"].append({"file": f, "dtype": dt, "shape": list(a.shape)})
        for i, o in enumerate(outs):
            f = f"{name}_out{i}.bin"
            o = np.asarray(o)
            _dump_bin(os.path.join(gdir, f), o)
            case["outputs"].append({
                "file": f, "dtype": "f32", "shape": list(o.shape)})
        cases.append(case)
    with open(os.path.join(gdir, "index.json"), "w") as f:
        json.dump({"cases": cases}, f, indent=1)


def build_config(cfg: ModelConfig, root: str, goldens: bool):
    outdir = os.path.join(root, cfg.name)
    os.makedirs(outdir, exist_ok=True)
    arts = executables(cfg)
    for name, (fn, specs) in arts.items():
        lowered = jax.jit(fn).lower(*specs)
        text = to_hlo_text(lowered)
        with open(os.path.join(outdir, f"{name}.hlo.txt"), "w") as f:
            f.write(text)
        print(f"  {cfg.name}/{name}: {len(text)} chars")
    with open(os.path.join(outdir, "manifest.json"), "w") as f:
        json.dump(manifest(cfg, arts), f, indent=1)
    if goldens:
        emit_goldens(cfg, outdir)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--configs", nargs="+", default=["tiny"])
    args = ap.parse_args()
    for name in args.configs:
        cfg = CONFIGS[name]
        print(f"lowering {name} ({total_params(cfg)/1e6:.1f}M params)")
        build_config(cfg, args.out, goldens=name.startswith("tiny"))


if __name__ == "__main__":
    main()
