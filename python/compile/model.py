"""L2: the OPT-style decoder model as *module-granular* JAX functions.

ZO2 disaggregates the model into (embedding, N transformer blocks, LM head)
and streams blocks through the GPU.  To let the rust coordinator drive that
schedule, each module is AOT-lowered to its own executable.  Three families:

  *_step  — the fused training executable (paper §5.4 "efficient parameter
            update"):  given a module's flat parameter bucket, it first
            applies the **deferred** update with the *previous* step's
            projected gradient `g_prev` and its replayed direction `z_prev`
            (a bit-exact no-op when g_prev == 0, i.e. the first step), then
            runs the dual (+eps / -eps) forward with the *current* direction
            `z_cur`.  One upload serves update + both forwards.
  *_fwd   — single unperturbed forward (evaluation / inference path).
  update  — standalone bucket update; used for the final flush after the
            last step (paper Fig. 6b: `model.opt.zo_update(model)`).

All perturbed matmuls go through the L1 Pallas kernel `zo_dual_matmul`
(weights + z streamed once, both products computed); all non-matmul
parameters (LayerNorm scales/shifts, biases, embedding tables) are perturbed
elementwise in jnp.  The perturbed weights never exist outside the kernel's
VMEM tiles / fused elementwise ops — exactly the paper's "in-place" property.

Buckets are flat f32 vectors with the layout defined in configs.py; the same
layout table is exported to rust via the artifact manifest.
"""

import jax
import jax.numpy as jnp

from .configs import (ModelConfig, block_layout, embed_layout, head_layout,
                      layout_offsets)
from .kernels import zo_dual_matmul, zo_update

LN_EPS = 1e-5


# --- bucket unpacking ------------------------------------------------------

def unpack(bucket, layout):
    """Flat f32 bucket -> dict of shaped views (static offsets)."""
    out = {}
    for name, off, shape in layout_offsets(layout):
        size = 1
        for s in shape:
            size *= s
        out[name] = bucket[off:off + size].reshape(shape)
    return out


# --- primitive dual helpers --------------------------------------------------

def dual_elem(w, z, eps):
    """Perturbed (+, -) views of a non-matmul parameter."""
    ez = eps * z
    return w + ez, w - ez


def layer_norm(x, w, b):
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mu), axis=-1, keepdims=True)
    return (x - mu) * jax.lax.rsqrt(var + LN_EPS) * w + b


def dual_linear(hp, hm, w, zw, b, zb, eps):
    """Dual perturbed affine layer over [..., K] activations."""
    k = w.shape[0]
    shp = hp.shape
    yp, ym = zo_dual_matmul(hp.reshape(-1, k), hm.reshape(-1, k), w, zw, eps)
    bp, bm = dual_elem(b, zb, eps)
    n = w.shape[1]
    return (yp + bp).reshape(shp[:-1] + (n,)), (ym + bm).reshape(shp[:-1] + (n,))


def causal_attention(q, k, v, cfg: ModelConfig):
    b, t, d = q.shape
    h, hd = cfg.n_heads, cfg.head_dim

    def split(x):
        return x.reshape(b, t, h, hd).transpose(0, 2, 1, 3)  # [B,H,T,hd]

    q, k, v = split(q), split(k), split(v)
    scores = jnp.einsum("bhqd,bhkd->bhqk", q, k) / jnp.sqrt(jnp.float32(hd))
    mask = jnp.tril(jnp.ones((t, t), dtype=bool))
    scores = jnp.where(mask, scores, jnp.float32(-1e30))
    probs = jax.nn.softmax(scores, axis=-1)
    ctx = jnp.einsum("bhqk,bhkd->bhqd", probs, v)
    return ctx.transpose(0, 2, 1, 3).reshape(b, t, d)


# --- module forwards ---------------------------------------------------------

def block_dual_fwd(cfg: ModelConfig, bucket, z, eps, hp, hm):
    p = unpack(bucket, block_layout(cfg))
    q = unpack(z, block_layout(cfg))

    ln1w_p, ln1w_m = dual_elem(p["ln1_w"], q["ln1_w"], eps)
    ln1b_p, ln1b_m = dual_elem(p["ln1_b"], q["ln1_b"], eps)
    ap = layer_norm(hp, ln1w_p, ln1b_p)
    am = layer_norm(hm, ln1w_m, ln1b_m)

    qp, qm = dual_linear(ap, am, p["wq"], q["wq"], p["bq"], q["bq"], eps)
    kp, km = dual_linear(ap, am, p["wk"], q["wk"], p["bk"], q["bk"], eps)
    vp, vm = dual_linear(ap, am, p["wv"], q["wv"], p["bv"], q["bv"], eps)
    cp = causal_attention(qp, kp, vp, cfg)
    cm = causal_attention(qm, km, vm, cfg)
    op_, om_ = dual_linear(cp, cm, p["wo"], q["wo"], p["bo"], q["bo"], eps)
    hp = hp + op_
    hm = hm + om_

    ln2w_p, ln2w_m = dual_elem(p["ln2_w"], q["ln2_w"], eps)
    ln2b_p, ln2b_m = dual_elem(p["ln2_b"], q["ln2_b"], eps)
    ap = layer_norm(hp, ln2w_p, ln2b_p)
    am = layer_norm(hm, ln2w_m, ln2b_m)
    fp, fm = dual_linear(ap, am, p["fc1_w"], q["fc1_w"], p["fc1_b"], q["fc1_b"], eps)
    fp = jax.nn.relu(fp)   # OPT uses ReLU activations
    fm = jax.nn.relu(fm)
    gp, gm = dual_linear(fp, fm, p["fc2_w"], q["fc2_w"], p["fc2_b"], q["fc2_b"], eps)
    return hp + gp, hm + gm


def block_fwd(cfg: ModelConfig, bucket, h):
    p = unpack(bucket, block_layout(cfg))
    a = layer_norm(h, p["ln1_w"], p["ln1_b"])
    q_ = a @ p["wq"] + p["bq"]
    k_ = a @ p["wk"] + p["bk"]
    v_ = a @ p["wv"] + p["bv"]
    h = h + (causal_attention(q_, k_, v_, cfg) @ p["wo"] + p["bo"])
    a = layer_norm(h, p["ln2_w"], p["ln2_b"])
    f = jax.nn.relu(a @ p["fc1_w"] + p["fc1_b"])
    return h + (f @ p["fc2_w"] + p["fc2_b"])


def embed_dual_fwd(cfg: ModelConfig, bucket, z, eps, ids):
    p = unpack(bucket, embed_layout(cfg))
    q = unpack(z, embed_layout(cfg))
    tok_p, tok_m = dual_elem(p["tok_emb"], q["tok_emb"], eps)
    pos_p, pos_m = dual_elem(p["pos_emb"], q["pos_emb"], eps)
    hp = tok_p[ids] + pos_p[None, :, :]
    hm = tok_m[ids] + pos_m[None, :, :]
    return hp, hm


def embed_fwd(cfg: ModelConfig, bucket, ids):
    p = unpack(bucket, embed_layout(cfg))
    return p["tok_emb"][ids] + p["pos_emb"][None, :, :]


def _next_token_loss(logits, ids):
    """Mean next-token cross-entropy over B*(T-1) positions."""
    lp = jax.nn.log_softmax(logits[:, :-1, :], axis=-1)
    tgt = ids[:, 1:]
    nll = -jnp.take_along_axis(lp, tgt[..., None], axis=-1)[..., 0]
    return jnp.mean(nll)


def head_dual_fwd(cfg: ModelConfig, bucket, z, eps, hp, hm, ids):
    p = unpack(bucket, head_layout(cfg))
    q = unpack(z, head_layout(cfg))
    lnw_p, lnw_m = dual_elem(p["lnf_w"], q["lnf_w"], eps)
    lnb_p, lnb_m = dual_elem(p["lnf_b"], q["lnf_b"], eps)
    ap = layer_norm(hp, lnw_p, lnb_p)
    am = layer_norm(hm, lnw_m, lnb_m)
    b, t, d = ap.shape
    lp, lm = zo_dual_matmul(ap.reshape(-1, d), am.reshape(-1, d),
                            p["lm_w"], q["lm_w"], eps)
    lp = lp.reshape(b, t, cfg.vocab)
    lm = lm.reshape(b, t, cfg.vocab)
    return _next_token_loss(lp, ids), _next_token_loss(lm, ids)


def head_eval(cfg: ModelConfig, bucket, h, ids):
    """Unperturbed loss + last-position logits (for label-token accuracy)."""
    p = unpack(bucket, head_layout(cfg))
    a = layer_norm(h, p["lnf_w"], p["lnf_b"])
    logits = a @ p["lm_w"]
    return _next_token_loss(logits, ids), logits[:, -1, :]


# --- fused step executables (deferred update + dual forward) -----------------
#
# The Gaussian directions are generated ON DEVICE from 8-byte keys (threefry,
# portable HLO) — the rust coordinator ships only the managed RNG *state*
# (paper §5.1), never a z vector.  This mirrors the real system (torch
# generator states on the GPU) and keeps the interconnect traffic equal to
# the parameter bytes alone.

def _zdraw(key_data, n):
    key = jax.random.wrap_key_data(key_data, impl="threefry2x32")
    z = jax.random.normal(key, (n,), jnp.float32)
    # Barrier: the draw must compile to the *same* rounding in every
    # executable that embeds it (fused step vs standalone update), or the
    # paper's bit-exactness guarantee (§5.1) breaks.  The barrier keeps the
    # generation chain out of surrounding fusions.
    return jax.lax.optimization_barrier(z)


def embed_step(cfg, bucket, key_prev, g_prev, lr, key_cur, eps, ids):
    b1 = zo_update(bucket, _zdraw(key_prev, bucket.shape[0]), lr, g_prev)
    hp, hm = embed_dual_fwd(cfg, b1, _zdraw(key_cur, bucket.shape[0]), eps, ids)
    return b1, hp, hm


def block_step(cfg, bucket, key_prev, g_prev, lr, key_cur, eps, hp, hm):
    b1 = zo_update(bucket, _zdraw(key_prev, bucket.shape[0]), lr, g_prev)
    op_, om_ = block_dual_fwd(cfg, b1, _zdraw(key_cur, bucket.shape[0]), eps, hp, hm)
    return b1, op_, om_


def head_step(cfg, bucket, key_prev, g_prev, lr, key_cur, eps, hp, hm, ids):
    b1 = zo_update(bucket, _zdraw(key_prev, bucket.shape[0]), lr, g_prev)
    lp, lm = head_dual_fwd(cfg, b1, _zdraw(key_cur, bucket.shape[0]), eps, hp, hm, ids)
    return b1, lp, lm


def update_bucket(bucket, key, lr, g):
    """Standalone flush executable — same kernel + key path as the in-step
    update, so the final flush is bit-identical by construction."""
    return zo_update(bucket, _zdraw(key, bucket.shape[0]), lr, g)
