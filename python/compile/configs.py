"""Model configurations and bucket layouts — the single source of truth.

The rust coordinator never sees python objects; it reads the manifest.json
emitted by aot.py, which serialises exactly what is defined here.  Any change
to the layout below therefore propagates to both sides through `make
artifacts`.

A *bucket* (paper §5.3, "communication buckets") is the flat, contiguous f32
vector holding every parameter of one module (embedding / transformer block /
LM head).  The rust side allocates, transfers, compresses and updates buckets;
the JAX side unpacks them into weight views inside each AOT-lowered
executable.  Layout order is the unpack order.
"""

from dataclasses import dataclass, field


@dataclass(frozen=True)
class ModelConfig:
    """An OPT-style decoder-only transformer, AOT-specialised to (B, T)."""

    name: str
    d_model: int
    n_heads: int
    n_layers: int
    vocab: int
    seq_len: int          # T fixed at AOT time (learned positional table size)
    batch: int            # B fixed at AOT time
    ffn_mult: int = 4

    @property
    def head_dim(self) -> int:
        assert self.d_model % self.n_heads == 0
        return self.d_model // self.n_heads

    @property
    def d_ffn(self) -> int:
        return self.ffn_mult * self.d_model


# --- bucket layouts -------------------------------------------------------
# Each entry: (param name, shape tuple).  Offsets are cumulative products.

def embed_layout(cfg: ModelConfig):
    return [
        ("tok_emb", (cfg.vocab, cfg.d_model)),
        ("pos_emb", (cfg.seq_len, cfg.d_model)),
    ]


def block_layout(cfg: ModelConfig):
    d, f = cfg.d_model, cfg.d_ffn
    return [
        ("ln1_w", (d,)), ("ln1_b", (d,)),
        ("wq", (d, d)), ("bq", (d,)),
        ("wk", (d, d)), ("bk", (d,)),
        ("wv", (d, d)), ("bv", (d,)),
        ("wo", (d, d)), ("bo", (d,)),
        ("ln2_w", (d,)), ("ln2_b", (d,)),
        ("fc1_w", (d, f)), ("fc1_b", (f,)),
        ("fc2_w", (f, d)), ("fc2_b", (d,)),
    ]


def head_layout(cfg: ModelConfig):
    return [
        ("lnf_w", (cfg.d_model,)), ("lnf_b", (cfg.d_model,)),
        ("lm_w", (cfg.d_model, cfg.vocab)),
    ]


def layout_size(layout) -> int:
    n = 0
    for _, shape in layout:
        m = 1
        for s in shape:
            m *= s
        n += m
    return n


def layout_offsets(layout):
    """[(name, offset, shape)] with offsets into the flat bucket."""
    out, off = [], 0
    for name, shape in layout:
        m = 1
        for s in shape:
            m *= s
        out.append((name, off, shape))
        off += m
    return out


def total_params(cfg: ModelConfig) -> int:
    return (
        layout_size(embed_layout(cfg))
        + cfg.n_layers * layout_size(block_layout(cfg))
        + layout_size(head_layout(cfg))
    )


# --- the config zoo -------------------------------------------------------
# `tiny*` are for tests; `gpt2-100m` is the end-to-end training example.
# The OPT family (paper Table 1) exists rust-side for the analytic /
# simulated experiments; only real-executable configs are listed here.

CONFIGS = {
    "tiny": ModelConfig("tiny", d_model=32, n_heads=2, n_layers=2,
                        vocab=64, seq_len=16, batch=2),
    "tiny-wide": ModelConfig("tiny-wide", d_model=48, n_heads=4, n_layers=3,
                             vocab=96, seq_len=8, batch=1),
    "gpt2-100m": ModelConfig("gpt2-100m", d_model=768, n_heads=12,
                             n_layers=12, vocab=8192, seq_len=32, batch=4),
}
