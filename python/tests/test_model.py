"""L2 model semantics: the fused step executables vs an Algorithm-1 oracle.

The oracle perturbs whole buckets (theta +/- eps*z) and runs the plain
single-forward model — exactly MeZO's monolithic view.  The production path
fuses the perturbation into the Pallas dual-matmul per linear layer.  Both
must agree, which validates the "perturb-inside-the-kernel" decomposition.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M
from compile.configs import (CONFIGS, block_layout, embed_layout, head_layout,
                             layout_offsets, layout_size, total_params)

CFG = CONFIGS["tiny"]


def _buckets(rng, cfg=CFG):
    pe = layout_size(embed_layout(cfg))
    pb = layout_size(block_layout(cfg))
    ph = layout_size(head_layout(cfg))
    mk = lambda p: rng.normal(0, 0.05, size=(p,)).astype(np.float32)
    return {
        "embed": mk(pe),
        "blocks": [mk(pb) for _ in range(cfg.n_layers)],
        "head": mk(ph),
    }


def _keys(rng, cfg=CFG):
    """Per-module threefry key data (what rust ships instead of z)."""
    mk = lambda: rng.randint(0, 2**31, size=(2,)).astype(np.uint32)
    return {"embed": mk(), "blocks": [mk() for _ in range(cfg.n_layers)], "head": mk()}


def _zs_from_keys(keys, cfg=CFG):
    """The z vectors the executables will generate on device."""
    import jax.random as jr

    def draw(k, n):
        return np.asarray(M._zdraw(k, n))

    from compile.configs import layout_size

    return {
        "embed": draw(keys["embed"], layout_size(embed_layout(cfg))),
        "blocks": [draw(k, layout_size(block_layout(cfg))) for k in keys["blocks"]],
        "head": draw(keys["head"], layout_size(head_layout(cfg))),
    }


def _ids(rng, cfg=CFG):
    return rng.randint(0, cfg.vocab, size=(cfg.batch, cfg.seq_len)).astype(np.int32)


def oracle_dual_losses(cfg, bk, zs, eps, ids):
    """Monolithic MeZO: perturb every bucket, run the plain eval forwards."""
    losses = []
    for sign in (+1.0, -1.0):
        e = M.embed_fwd(cfg, bk["embed"] + sign * eps * zs["embed"], ids)
        h = e
        for wb, zb in zip(bk["blocks"], zs["blocks"]):
            h = M.block_fwd(cfg, wb + sign * eps * zb, h)
        loss, _ = M.head_eval(cfg, bk["head"] + sign * eps * zs["head"], h, ids)
        losses.append(loss)
    return losses


def fused_dual_losses(cfg, bk, keys, eps, ids):
    """Production path: compose the *_step executables with g_prev = 0."""
    zero = jnp.float32(0.0)
    lr = jnp.float32(1e-4)
    eps = jnp.float32(eps)
    _, hp, hm = M.embed_step(cfg, bk["embed"], keys["embed"], zero, lr,
                             keys["embed"], eps, ids)
    for wb, kb in zip(bk["blocks"], keys["blocks"]):
        _, hp, hm = M.block_step(cfg, wb, kb, zero, lr, kb, eps, hp, hm)
    _, lp, lm = M.head_step(cfg, bk["head"], keys["head"], zero,
                            lr, keys["head"], eps, hp, hm, ids)
    return lp, lm


@pytest.mark.parametrize("seed,eps", [(0, 1e-3), (1, 1e-2), (2, 1e-4)])
def test_fused_step_matches_monolithic_mezo(seed, eps):
    rng = np.random.RandomState(seed)
    bk, keys, ids = _buckets(rng), _keys(rng), _ids(rng)
    zs = _zs_from_keys(keys)  # replay exactly what the device generates
    lo_p, lo_m = oracle_dual_losses(CFG, bk, zs, eps, ids)
    lf_p, lf_m = fused_dual_losses(CFG, bk, keys, eps, ids)
    np.testing.assert_allclose(lf_p, lo_p, rtol=2e-5, atol=2e-6)
    np.testing.assert_allclose(lf_m, lo_m, rtol=2e-5, atol=2e-6)


def test_deferred_update_equals_update_then_forward():
    """step(bucket, key_prev, g_prev) == step(update(bucket,...), 0-g)."""
    rng = np.random.RandomState(7)
    bk, keys, ids = _buckets(rng), _keys(rng), _ids(rng)
    kp = _keys(np.random.RandomState(8))
    g = jnp.float32(1.7)
    lr = jnp.float32(1e-3)
    eps = jnp.float32(1e-3)
    wb, kb, kprev = bk["blocks"][0], keys["blocks"][0], kp["blocks"][0]
    hp = rng.normal(0, 1, (CFG.batch, CFG.seq_len, CFG.d_model)).astype(np.float32)
    hm = hp + 0.01

    b1, op1, om1 = M.block_step(CFG, wb, kprev, g, lr, kb, eps, hp, hm)
    upd = M.update_bucket(wb, kprev, lr, g)
    b2, op2, om2 = M.block_step(CFG, np.asarray(upd), kprev,
                                jnp.float32(0.0), lr, kb, eps, hp, hm)
    # Same kernel path on both sides -> bit-exact.
    assert np.array_equal(np.asarray(b1), np.asarray(b2))
    assert np.array_equal(np.asarray(op1), np.asarray(op2))
    assert np.array_equal(np.asarray(om1), np.asarray(om2))


def test_head_eval_loss_is_next_token_ce():
    rng = np.random.RandomState(11)
    bk, ids = _buckets(rng), _ids(rng)
    h = rng.normal(0, 1, (CFG.batch, CFG.seq_len, CFG.d_model)).astype(np.float32)
    loss, last = M.head_eval(CFG, bk["head"], h, ids)
    p = M.unpack(bk["head"], head_layout(CFG))
    a = M.layer_norm(h, p["lnf_w"], p["lnf_b"])
    logits = a @ p["lm_w"]
    lp = jax.nn.log_softmax(logits[:, :-1], axis=-1)
    want = -np.mean(np.take_along_axis(np.asarray(lp), ids[:, 1:, None], axis=-1))
    np.testing.assert_allclose(loss, want, rtol=1e-5)
    assert last.shape == (CFG.batch, CFG.vocab)


def test_layouts_are_dense_and_ordered():
    for layout_fn in (embed_layout, block_layout, head_layout):
        lay = layout_fn(CFG)
        off = 0
        for name, o, shape in layout_offsets(lay):
            assert o == off
            n = int(np.prod(shape))
            off += n
        assert off == layout_size(lay)


def test_total_params_gpt2_100m_band():
    n = total_params(CONFIGS["gpt2-100m"])
    assert 85e6 < n < 120e6, n


def test_perturbation_symmetry():
    """loss(+eps) and loss(-eps) collapse to the same value when eps == 0."""
    rng = np.random.RandomState(13)
    bk, keys, ids = _buckets(rng), _keys(rng), _ids(rng)
    lp, lm = fused_dual_losses(CFG, bk, keys, 0.0, ids)
    assert np.array_equal(np.asarray(lp), np.asarray(lm))


def test_projected_gradient_matches_directional_derivative():
    """(l+ - l-)/2eps ~= z . grad L  for small eps (RGE sanity, Eq. 2)."""
    rng = np.random.RandomState(17)
    bk, ids = _buckets(rng), _ids(rng)
    zs = _zs_from_keys(_keys(rng))
    eps = 1e-4

    def full_loss(flat):
        pe = layout_size(embed_layout(CFG))
        pb = layout_size(block_layout(CFG))
        embed = flat[:pe]
        blocks = [flat[pe + i * pb: pe + (i + 1) * pb] for i in range(CFG.n_layers)]
        head = flat[pe + CFG.n_layers * pb:]
        h = M.embed_fwd(CFG, embed, ids)
        for b in blocks:
            h = M.block_fwd(CFG, b, h)
        loss, _ = M.head_eval(CFG, head, h, ids)
        return loss

    flat = np.concatenate([bk["embed"], *bk["blocks"], bk["head"]])
    zflat = np.concatenate([zs["embed"], *zs["blocks"], zs["head"]])
    lp = full_loss(flat + eps * zflat)
    lm = full_loss(flat - eps * zflat)
    g = (lp - lm) / (2 * eps)
    grad = jax.grad(full_loss)(flat)
    want = float(np.dot(np.asarray(grad), zflat))
    np.testing.assert_allclose(float(g), want, rtol=5e-2, atol=5e-3)
