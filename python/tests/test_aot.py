"""AOT path: lowering smoke, manifest shape, golden replay."""

import json
import os

import jax
import numpy as np
import pytest

from compile import aot
from compile.configs import CONFIGS

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts", "tiny")


def test_lower_tiny_block_step_hlo_text():
    cfg = CONFIGS["tiny"]
    exes = aot.executables(cfg)
    fn, specs = exes["block_step"]
    text = aot.to_hlo_text(jax.jit(fn).lower(*specs))
    assert text.startswith("HloModule")
    assert "f32[12704]" in text  # tiny block bucket size


def test_manifest_contents():
    cfg = CONFIGS["tiny"]
    m = aot.manifest(cfg, aot.executables(cfg))
    assert m["config"]["n_layers"] == 2
    assert m["buckets"]["block"]["size"] == 12704
    names = [e["name"] for e in m["buckets"]["block"]["layout"]]
    assert names[0] == "ln1_w" and names[-1] == "fc2_b"
    assert set(m["artifacts"]) == {
        "embed_step", "block_step", "head_step", "embed_fwd", "block_fwd",
        "head_eval", "update_embed", "update_block", "update_head"}


@pytest.mark.skipif(not os.path.isdir(os.path.join(ART, "golden")),
                    reason="run `make artifacts` first")
def test_golden_replay_bit_exact():
    """Re-executing the jitted fns on the dumped inputs reproduces outputs."""
    cfg = CONFIGS["tiny"]
    gdir = os.path.join(ART, "golden")
    with open(os.path.join(gdir, "index.json")) as f:
        index = json.load(f)
    exes = aot.executables(cfg)
    for case in index["cases"]:
        fn, _ = exes[case["exe"]]
        args = []
        for meta in case["inputs"]:
            dt = {"i32": np.int32, "u32": np.uint32}.get(meta["dtype"], np.float32)
            a = np.fromfile(os.path.join(gdir, meta["file"]), dtype=dt)
            args.append(a.reshape(meta["shape"]) if meta["shape"] else dt(a[0]))
        outs = jax.jit(fn)(*args)
        if not isinstance(outs, (tuple, list)):
            outs = (outs,)
        for got, meta in zip(outs, case["outputs"]):
            want = np.fromfile(os.path.join(gdir, meta["file"]), dtype=np.float32)
            got = np.asarray(got).reshape(-1)
            assert np.array_equal(got, want), case["exe"]
