"""L1 kernel correctness: Pallas vs pure-jnp oracle, hypothesis-swept."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import choose_block, vmem_bytes, zo_dual_matmul, zo_update
from compile.kernels.ref import zo_dual_matmul_ref, zo_update_ref

DIMS = st.integers(min_value=1, max_value=96)


def _rand(rng, *shape, dtype=np.float32):
    return rng.normal(0, 1, size=shape).astype(dtype)


@settings(max_examples=25, deadline=None)
@given(m=DIMS, k=DIMS, n=DIMS,
       eps=st.floats(min_value=1e-6, max_value=1e-1),
       seed=st.integers(min_value=0, max_value=2**31 - 1))
def test_dual_matmul_matches_ref(m, k, n, eps, seed):
    rng = np.random.RandomState(seed)
    xp, xm = _rand(rng, m, k), _rand(rng, m, k)
    w, z = _rand(rng, k, n), _rand(rng, k, n)
    eps = jnp.float32(eps)
    yp, ym = jax.jit(zo_dual_matmul)(xp, xm, w, z, eps)
    rp, rm = zo_dual_matmul_ref(xp, xm, w, z, eps)
    np.testing.assert_allclose(yp, rp, rtol=2e-5, atol=2e-5)
    np.testing.assert_allclose(ym, rm, rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("m,k,n", [
    (128, 768, 768),     # gpt2-100m qkv/o projection shape
    (128, 768, 3072),    # fc1
    (128, 3072, 768),    # fc2
    (2, 3, 5),           # prime-ish dims -> single-tile fallback
    (1, 1, 1),
])
def test_dual_matmul_paper_shapes(m, k, n):
    rng = np.random.RandomState(1)
    xp, xm = _rand(rng, m, k), _rand(rng, m, k)
    w, z = _rand(rng, k, n), _rand(rng, k, n)
    eps = jnp.float32(1e-3)
    yp, ym = jax.jit(zo_dual_matmul)(xp, xm, w, z, eps)
    rp, rm = zo_dual_matmul_ref(xp, xm, w, z, eps)
    np.testing.assert_allclose(yp, rp, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(ym, rm, rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16, jnp.float16])
def test_dual_matmul_low_bit_storage(dtype):
    """AMP mode stores low-bit; the kernel must upcast and stay close."""
    rng = np.random.RandomState(2)
    xp = jnp.asarray(_rand(rng, 8, 16), dtype)
    xm = jnp.asarray(_rand(rng, 8, 16), dtype)
    w = jnp.asarray(_rand(rng, 16, 12), dtype)
    z = jnp.asarray(_rand(rng, 16, 12), dtype)
    eps = jnp.float32(1e-2)
    yp, ym = jax.jit(zo_dual_matmul)(xp, xm, w, z, eps)
    rp, rm = zo_dual_matmul_ref(xp.astype(jnp.float32), xm.astype(jnp.float32),
                                w.astype(jnp.float32), z.astype(jnp.float32), eps)
    tol = 1e-5 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(yp, rp, rtol=tol, atol=tol)
    np.testing.assert_allclose(ym, rm, rtol=tol, atol=tol)


@settings(max_examples=25, deadline=None)
@given(p=st.integers(min_value=1, max_value=5000),
       lr=st.floats(min_value=1e-8, max_value=1e-2),
       g=st.floats(min_value=-10, max_value=10),
       seed=st.integers(min_value=0, max_value=2**31 - 1))
def test_update_matches_ref(p, lr, g, seed):
    rng = np.random.RandomState(seed)
    b, z = _rand(rng, p), _rand(rng, p)
    lr, g = jnp.float32(lr), jnp.float32(g)
    got = jax.jit(zo_update)(b, z, lr, g)
    want = zo_update_ref(b, z, lr, g)
    np.testing.assert_allclose(got, want, rtol=1e-6, atol=1e-7)


def test_update_zero_g_is_exact_noop():
    """First-step deferred update (g_prev = 0) must be bit-exact identity."""
    rng = np.random.RandomState(3)
    b, z = _rand(rng, 4096), _rand(rng, 4096)
    got = jax.jit(zo_update)(b, z, jnp.float32(1e-4), jnp.float32(0.0))
    assert np.array_equal(np.asarray(got), b)


def test_pick_tile_small_grids_for_real_bucket_sizes():
    """The flat-bucket tiler must never explode the pallas grid (the
    gpt2-100m block bucket is 7,087,872 = 2^8·3·11·839 — a naive divisor
    walk once produced an 18,458-step grid and minutes-long steps)."""
    from compile.kernels.zo_update import pick_tile, BP_CAP

    for p in [7_087_872, 6_316_032, 6_292_992, 12_704, 1, 97, 1 << 22]:
        tile = pick_tile(p, BP_CAP)
        assert p % tile == 0
        grid = p // tile
        assert grid <= 64 or tile == p, (p, tile, grid)


def test_choose_block_divides():
    for dim in [1, 2, 7, 32, 97, 128, 768, 3072, 8192, 12288]:
        for cap in [8, 128, 512, 1024, 2048]:
            blk = choose_block(dim, cap)
            assert dim % blk == 0
            assert blk <= max(cap, dim if dim <= cap else dim)


def test_vmem_budget_paper_scale():
    """The chosen tiles must fit a TPU core's ~16MB VMEM at OPT-175B dims."""
    assert vmem_bytes(2048, 12288, 12288) < 16 * 1024 * 1024
    assert vmem_bytes(128, 3072, 768) < 16 * 1024 * 1024
